//! Tiny benchmark harness (criterion is unavailable offline).
//!
//! Provides warmed, repeated measurement with median/MAD reporting and a
//! stable text output format shared by every `cargo bench` target:
//!
//! ```text
//! bench <name> ... median 12.345 ms  (n=20, mad 1.2%)  [optional throughput]
//! ```
//!
//! [`BenchReport`] additionally collects rows into a machine-readable
//! JSON file (e.g. `BENCH_kernels.json` from the `fig13_kernels` bench)
//! so successive PRs have a throughput-regression baseline; see
//! `docs/performance.md` for the tracked numbers.

use std::time::Instant;

use crate::util::stats::median;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// median seconds per iteration
    pub median_s: f64,
    /// median absolute deviation, relative
    pub mad_rel: f64,
    pub iters: usize,
}

impl Measurement {
    /// Throughput in GB/s given bytes moved per iteration.
    pub fn gbps(&self, bytes: usize) -> f64 {
        bytes as f64 / self.median_s / 1e9
    }
}

/// Run `f` repeatedly: `warmup` unmeasured runs, then `iters` timed runs.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let med = median(&times);
    let devs: Vec<f64> = times.iter().map(|t| (t - med).abs()).collect();
    let mad = median(&devs);
    Measurement {
        name: name.to_string(),
        median_s: med,
        mad_rel: if med > 0.0 { mad / med } else { 0.0 },
        iters,
    }
}

/// Auto-tuned iteration count: keep each benchmark around `budget_s`.
pub fn bench_auto(name: &str, budget_s: f64, mut f: impl FnMut()) -> Measurement {
    let t0 = Instant::now();
    f(); // warmup + calibration
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget_s / once).ceil() as usize).clamp(3, 1000);
    bench(name, 1, iters, f)
}

/// Print a measurement in the standard format, with optional GB/s.
pub fn report(m: &Measurement, bytes: Option<usize>) {
    let time = if m.median_s >= 1.0 {
        format!("{:.3} s ", m.median_s)
    } else if m.median_s >= 1e-3 {
        format!("{:.3} ms", m.median_s * 1e3)
    } else {
        format!("{:.1} µs", m.median_s * 1e6)
    };
    let tp = bytes
        .map(|b| format!("  {:.2} GB/s", m.gbps(b)))
        .unwrap_or_default();
    println!(
        "bench {:<44} median {}  (n={}, mad {:.1}%){}",
        m.name,
        time,
        m.iters,
        m.mad_rel * 100.0,
        tp
    );
}

/// One row of a machine-readable kernel report: a `(kernel, variant,
/// dtype, shape, axis)` cell with its timing and throughput.
#[derive(Clone, Debug)]
pub struct ReportRow {
    /// Kernel family: "GPK", "LPK", "IPK", ...
    pub kernel: String,
    /// Measurement variant: "serial", "parallel", "baseline",
    /// "serial-total", "parallel-total", ...
    pub variant: String,
    /// Element type: "f32" / "f64".
    pub dtype: String,
    /// Buffer shape the kernel ran on.
    pub shape: Vec<usize>,
    /// Processed axis, or `None` for per-family aggregate rows.
    pub axis: Option<usize>,
    /// Median seconds per iteration.
    pub median_s: f64,
    /// Relative median absolute deviation.
    pub mad_rel: f64,
    /// Throughput in GB/s over the row's nominal byte volume.
    pub gbps: f64,
    /// Speedup vs the serial variant of the same cell, when applicable.
    pub speedup: Option<f64>,
    /// Output size in bytes, when the row describes an encoder (the
    /// container bench's per-class size breakdown).
    pub bytes: Option<u64>,
    /// Nominal compulsory memory traffic of one iteration, bytes — the
    /// numerator of the roofline position (`docs/performance.md`).
    pub bytes_moved: Option<u64>,
    /// Achieved throughput as a percentage of the report's measured
    /// memory-bandwidth peak (`peak_gbps`).
    pub pct_peak: Option<f64>,
}

impl Default for ReportRow {
    /// Empty cell: fill the fields a bench measures, leave the rest.
    fn default() -> Self {
        ReportRow {
            kernel: String::new(),
            variant: String::new(),
            dtype: String::new(),
            shape: Vec::new(),
            axis: None,
            median_s: 0.0,
            mad_rel: 0.0,
            gbps: 0.0,
            speedup: None,
            bytes: None,
            bytes_moved: None,
            pct_peak: None,
        }
    }
}

impl ReportRow {
    /// Set the roofline fields from a byte volume and the measured peak:
    /// `bytes_moved`, recomputed `gbps`, and `pct_peak` when a peak is
    /// known.
    pub fn with_roofline(mut self, bytes_moved: u64, peak_gbps: Option<f64>) -> Self {
        self.bytes_moved = Some(bytes_moved);
        if self.median_s > 0.0 {
            self.gbps = bytes_moved as f64 / self.median_s / 1e9;
        }
        self.pct_peak = peak_gbps
            .filter(|&p| p > 0.0)
            .map(|p| 100.0 * self.gbps / p);
        self
    }
}

/// Collected bench rows plus run metadata, serializable to JSON.
#[derive(Clone, Debug)]
pub struct BenchReport {
    pub name: String,
    /// Worker count the parallel variants ran with.
    pub threads: usize,
    /// Measured read+write stream bandwidth of the machine the report
    /// was produced on, GB/s ([`crate::simgpu::calibrate::measure_peak_gbps`]);
    /// the denominator of every row's `pct_peak`.
    pub peak_gbps: Option<f64>,
    pub rows: Vec<ReportRow>,
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.6e}")
    } else {
        "null".to_string()
    }
}

impl BenchReport {
    pub fn new(name: &str) -> Self {
        BenchReport {
            name: name.to_string(),
            threads: crate::util::par::threads(),
            peak_gbps: None,
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: ReportRow) {
        self.rows.push(row);
    }

    /// Serialize to a stable, diff-friendly JSON document.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"name\": {},\n", json_str(&self.name)));
        out.push_str(&format!("  \"threads\": {},\n", self.threads));
        out.push_str(&format!(
            "  \"peak_gbps\": {},\n",
            self.peak_gbps.map_or("null".to_string(), json_f64)
        ));
        out.push_str("  \"rows\": [\n");
        for (i, r) in self.rows.iter().enumerate() {
            let shape: Vec<String> = r.shape.iter().map(|n| n.to_string()).collect();
            out.push_str(&format!(
                "    {{\"kernel\": {}, \"variant\": {}, \"dtype\": {}, \"shape\": [{}], \
                 \"axis\": {}, \"median_s\": {}, \"mad_rel\": {}, \"gbps\": {}, \"speedup\": {}, \
                 \"bytes\": {}, \"bytes_moved\": {}, \"pct_peak\": {}}}{}\n",
                json_str(&r.kernel),
                json_str(&r.variant),
                json_str(&r.dtype),
                shape.join(", "),
                r.axis.map_or("null".to_string(), |a| a.to_string()),
                json_f64(r.median_s),
                json_f64(r.mad_rel),
                json_f64(r.gbps),
                r.speedup.map_or("null".to_string(), json_f64),
                r.bytes.map_or("null".to_string(), |b| b.to_string()),
                r.bytes_moved.map_or("null".to_string(), |b| b.to_string()),
                r.pct_peak.map_or("null".to_string(), json_f64),
                if i + 1 < self.rows.len() { "," } else { "" },
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write the JSON document to `path`.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut x = 0u64;
        let m = bench("spin", 1, 5, || {
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
        });
        assert!(m.median_s > 0.0);
        assert_eq!(m.iters, 5);
        std::hint::black_box(x);
    }

    #[test]
    fn report_json_parses_back() {
        let mut rep = BenchReport::new("unit \"test\"");
        rep.peak_gbps = Some(40.0);
        rep.push(
            ReportRow {
                kernel: "LPK".into(),
                variant: "parallel".into(),
                dtype: "f64".into(),
                shape: vec![129, 129, 129],
                axis: Some(0),
                median_s: 1.0e-3,
                mad_rel: 0.01,
                gbps: 13.7,
                speedup: Some(1.9),
                bytes: Some(4096),
                ..Default::default()
            }
            .with_roofline(10_000_000, rep.peak_gbps),
        );
        rep.push(ReportRow {
            kernel: "LPK".into(),
            variant: "serial-total".into(),
            dtype: "f64".into(),
            shape: vec![129, 129, 129],
            axis: None,
            median_s: 4.0e-3,
            gbps: 4.2,
            ..Default::default()
        });
        let doc = crate::util::json::parse(&rep.to_json()).expect("valid JSON");
        assert_eq!(doc.get("name").unwrap().as_str().unwrap(), "unit \"test\"");
        assert!((doc.get("peak_gbps").unwrap().as_f64().unwrap() - 40.0).abs() < 1e-9);
        let rows = doc.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("axis").unwrap().as_usize(), Some(0));
        assert_eq!(rows[0].get("bytes").unwrap().as_usize(), Some(4096));
        // with_roofline: 10 MB in 1 ms = 10 GB/s = 25% of the 40 GB/s peak
        assert_eq!(rows[0].get("bytes_moved").unwrap().as_usize(), Some(10_000_000));
        assert!((rows[0].get("gbps").unwrap().as_f64().unwrap() - 10.0).abs() < 1e-9);
        assert!((rows[0].get("pct_peak").unwrap().as_f64().unwrap() - 25.0).abs() < 1e-9);
        assert!(rows[1].get("speedup").unwrap().as_f64().is_none());
        assert!(rows[1].get("bytes").unwrap().as_usize().is_none());
        assert!(rows[1].get("pct_peak").unwrap().as_f64().is_none());
        assert!((rows[0].get("speedup").unwrap().as_f64().unwrap() - 1.9).abs() < 1e-9);
    }

    #[test]
    fn gbps_math() {
        let m = Measurement {
            name: "x".into(),
            median_s: 0.5,
            mad_rel: 0.0,
            iters: 1,
        };
        assert!((m.gbps(1_000_000_000) - 2.0).abs() < 1e-12);
    }
}
