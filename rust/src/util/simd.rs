//! Stride-1 SIMD fast paths for the refactoring inner loops.
//!
//! The §3.3 reordered-gather layout makes every kernel line contiguous, so
//! the hot loops in [`crate::refactor::axis`] are straight runs of fused
//! multiply-adds over stride-1 slices. Without `-C target-feature=+fma`
//! those `mul_add` calls lower to libm `fma()` — a function call per
//! element. This module provides runtime-dispatched AVX2+FMA row
//! primitives that keep the *exact* per-lane operation sequence of the
//! scalar code, so results are **bit-identical** to the scalar path (the
//! same invariant the parallel layer upholds; asserted by
//! `tests/simd_matrix.rs`).
//!
//! Design rules, in order of importance:
//!
//! 1. **Bit-identity.** Every vector op is an element-wise `loadu` /
//!    broadcast / `fmadd` / `mul` / `add` / `sub` / `storeu` — the same
//!    rounding sequence per lane as the scalar formula. No horizontal
//!    reductions, no shuffles, no re-association, no approximate
//!    reciprocals, and no vector `round` (whose half-to-even tie rule
//!    differs from `f64::round` — which is why the quantizer keeps its
//!    scalar `.round()` inside a chunked loop instead of using this
//!    module).
//! 2. **Scalar twin.** Every dispatching entry point `op(..)` has a public
//!    `op_scalar(..)` reference implementation; off the fast path (non-x86
//!    targets, missing CPU features, `MGR_NO_SIMD`, or a remainder tail)
//!    the dispatcher computes exactly what the twin computes.
//! 3. **Dispatch once.** CPU-feature detection is cached in an atomic;
//!    the per-row dispatch cost is one relaxed load and a `TypeId`
//!    comparison that constant-folds after monomorphization.
//!
//! Set `MGR_NO_SIMD=1` to force the scalar paths process-wide (read once,
//! like the [`crate::util::par`] knobs).

use std::sync::atomic::{AtomicU8, Ordering};

use crate::util::Scalar;

/// Detection cache states.
const UNKNOWN: u8 = 0;
const ON: u8 = 1;
const OFF: u8 = 2;

static STATE: AtomicU8 = AtomicU8::new(UNKNOWN);

/// True when the AVX2+FMA fast paths are active on this host (feature
/// detection succeeded and `MGR_NO_SIMD` is unset). Cached after the
/// first call.
pub fn enabled() -> bool {
    match STATE.load(Ordering::Relaxed) {
        ON => true,
        OFF => false,
        _ => {
            let on = detect();
            STATE.store(if on { ON } else { OFF }, Ordering::Relaxed);
            on
        }
    }
}

fn detect() -> bool {
    if std::env::var_os("MGR_NO_SIMD").is_some_and(|v| !v.is_empty() && v != "0") {
        return false;
    }
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Reinterpret `&[T]` as `&[U]` when `T` and `U` are the same type
/// (monomorphization-time dispatch; the branch constant-folds away).
#[cfg(target_arch = "x86_64")]
#[inline(always)]
fn cast<T: 'static, U: 'static>(s: &[T]) -> Option<&[U]> {
    if std::any::TypeId::of::<T>() == std::any::TypeId::of::<U>() {
        // SAFETY: TypeId equality proves T and U are the same type, so the
        // layout (and every bit pattern) is identical.
        Some(unsafe { &*(s as *const [T] as *const [U]) })
    } else {
        None
    }
}

#[cfg(target_arch = "x86_64")]
#[inline(always)]
fn cast_mut<T: 'static, U: 'static>(s: &mut [T]) -> Option<&mut [U]> {
    if std::any::TypeId::of::<T>() == std::any::TypeId::of::<U>() {
        // SAFETY: as in `cast` — same type, same layout.
        Some(unsafe { &mut *(s as *mut [T] as *mut [U]) })
    } else {
        None
    }
}

/// `out[e] = fma(r, hi[e], fma(-r, lo[e], lo[e]))` — the GPK odd-row
/// interpolant with a row-constant ratio.
#[inline]
pub fn interp_row<T: Scalar>(lo: &[T], hi: &[T], r: T, out: &mut [T]) {
    #[cfg(target_arch = "x86_64")]
    if enabled() {
        if let (Some(lo), Some(hi), Some(out)) =
            (cast::<T, f64>(lo), cast::<T, f64>(hi), cast_mut::<T, f64>(out))
        {
            // SAFETY: `enabled()` verified AVX2+FMA at runtime.
            unsafe { x86::interp_row_f64(lo, hi, r.to_f64(), out) };
            return;
        }
        if let (Some(lo), Some(hi), Some(out)) =
            (cast::<T, f32>(lo), cast::<T, f32>(hi), cast_mut::<T, f32>(out))
        {
            // SAFETY: `enabled()` verified AVX2+FMA at runtime.
            unsafe { x86::interp_row_f32(lo, hi, r.to_f64() as f32, out) };
            return;
        }
    }
    interp_row_scalar(lo, hi, r, out);
}

/// Scalar reference for [`interp_row`].
#[inline]
pub fn interp_row_scalar<T: Scalar>(lo: &[T], hi: &[T], r: T, out: &mut [T]) {
    for e in 0..out.len() {
        out[e] = r.mul_add(hi[e], (-r).mul_add(lo[e], lo[e]));
    }
}

/// [`interp_row`] with a per-element ratio vector (the fused last-axis
/// upsample, where the row index *is* the coarse axis).
#[inline]
pub fn interp_row_vr<T: Scalar>(lo: &[T], hi: &[T], r: &[T], out: &mut [T]) {
    #[cfg(target_arch = "x86_64")]
    if enabled() {
        if let (Some(lo), Some(hi), Some(r), Some(out)) = (
            cast::<T, f64>(lo),
            cast::<T, f64>(hi),
            cast::<T, f64>(r),
            cast_mut::<T, f64>(out),
        ) {
            // SAFETY: `enabled()` verified AVX2+FMA at runtime.
            unsafe { x86::interp_row_vr_f64(lo, hi, r, out) };
            return;
        }
        if let (Some(lo), Some(hi), Some(r), Some(out)) = (
            cast::<T, f32>(lo),
            cast::<T, f32>(hi),
            cast::<T, f32>(r),
            cast_mut::<T, f32>(out),
        ) {
            // SAFETY: `enabled()` verified AVX2+FMA at runtime.
            unsafe { x86::interp_row_vr_f32(lo, hi, r, out) };
            return;
        }
    }
    interp_row_vr_scalar(lo, hi, r, out);
}

/// Scalar reference for [`interp_row_vr`].
#[inline]
pub fn interp_row_vr_scalar<T: Scalar>(lo: &[T], hi: &[T], r: &[T], out: &mut [T]) {
    for e in 0..out.len() {
        out[e] = r[e].mul_add(hi[e], (-r[e]).mul_add(lo[e], lo[e]));
    }
}

/// `odd[e] -= fma(r, hi[e], fma(-r, lo[e], lo[e]))` — single-axis GPK
/// coefficients (value minus interpolant), in place.
#[inline]
pub fn interp_sub_row<T: Scalar>(lo: &[T], hi: &[T], r: T, odd: &mut [T]) {
    #[cfg(target_arch = "x86_64")]
    if enabled() {
        if let (Some(lo), Some(hi), Some(odd)) =
            (cast::<T, f64>(lo), cast::<T, f64>(hi), cast_mut::<T, f64>(odd))
        {
            // SAFETY: `enabled()` verified AVX2+FMA at runtime.
            unsafe { x86::interp_sub_row_f64(lo, hi, r.to_f64(), odd) };
            return;
        }
        if let (Some(lo), Some(hi), Some(odd)) =
            (cast::<T, f32>(lo), cast::<T, f32>(hi), cast_mut::<T, f32>(odd))
        {
            // SAFETY: `enabled()` verified AVX2+FMA at runtime.
            unsafe { x86::interp_sub_row_f32(lo, hi, r.to_f64() as f32, odd) };
            return;
        }
    }
    interp_sub_row_scalar(lo, hi, r, odd);
}

/// Scalar reference for [`interp_sub_row`].
#[inline]
pub fn interp_sub_row_scalar<T: Scalar>(lo: &[T], hi: &[T], r: T, odd: &mut [T]) {
    for e in 0..odd.len() {
        let interp = r.mul_add(hi[e], (-r).mul_add(lo[e], lo[e]));
        odd[e] -= interp;
    }
}

/// Inverse of [`interp_sub_row`]: `odd[e] += interpolant`.
#[inline]
pub fn interp_add_row<T: Scalar>(lo: &[T], hi: &[T], r: T, odd: &mut [T]) {
    #[cfg(target_arch = "x86_64")]
    if enabled() {
        if let (Some(lo), Some(hi), Some(odd)) =
            (cast::<T, f64>(lo), cast::<T, f64>(hi), cast_mut::<T, f64>(odd))
        {
            // SAFETY: `enabled()` verified AVX2+FMA at runtime.
            unsafe { x86::interp_add_row_f64(lo, hi, r.to_f64(), odd) };
            return;
        }
        if let (Some(lo), Some(hi), Some(odd)) =
            (cast::<T, f32>(lo), cast::<T, f32>(hi), cast_mut::<T, f32>(odd))
        {
            // SAFETY: `enabled()` verified AVX2+FMA at runtime.
            unsafe { x86::interp_add_row_f32(lo, hi, r.to_f64() as f32, odd) };
            return;
        }
    }
    interp_add_row_scalar(lo, hi, r, odd);
}

/// Scalar reference for [`interp_add_row`].
#[inline]
pub fn interp_add_row_scalar<T: Scalar>(lo: &[T], hi: &[T], r: T, odd: &mut [T]) {
    for e in 0..odd.len() {
        let interp = r.mul_add(hi[e], (-r).mul_add(lo[e], lo[e]));
        odd[e] += interp;
    }
}

/// The LPK fused five-tap row:
/// `out = fma(t4, r4, fma(t3, r3, fma(t2, r2, fma(t0, r0, t1*r1))))`.
#[inline]
pub fn five_tap_row<T: Scalar>(taps: [T; 5], rows: [&[T]; 5], out: &mut [T]) {
    #[cfg(target_arch = "x86_64")]
    if enabled() {
        if let (Some(r0), Some(r1), Some(r2), Some(r3), Some(r4), Some(o)) = (
            cast::<T, f64>(rows[0]),
            cast::<T, f64>(rows[1]),
            cast::<T, f64>(rows[2]),
            cast::<T, f64>(rows[3]),
            cast::<T, f64>(rows[4]),
            cast_mut::<T, f64>(out),
        ) {
            let t = taps.map(Scalar::to_f64);
            // SAFETY: `enabled()` verified AVX2+FMA at runtime.
            unsafe { x86::five_tap_row_f64(t, [r0, r1, r2, r3, r4], o) };
            return;
        }
        if let (Some(r0), Some(r1), Some(r2), Some(r3), Some(r4), Some(o)) = (
            cast::<T, f32>(rows[0]),
            cast::<T, f32>(rows[1]),
            cast::<T, f32>(rows[2]),
            cast::<T, f32>(rows[3]),
            cast::<T, f32>(rows[4]),
            cast_mut::<T, f32>(out),
        ) {
            let t = taps.map(|v| v.to_f64() as f32);
            // SAFETY: `enabled()` verified AVX2+FMA at runtime.
            unsafe { x86::five_tap_row_f32(t, [r0, r1, r2, r3, r4], o) };
            return;
        }
    }
    five_tap_row_scalar(taps, rows, out);
}

/// Scalar reference for [`five_tap_row`].
#[inline]
pub fn five_tap_row_scalar<T: Scalar>(taps: [T; 5], rows: [&[T]; 5], out: &mut [T]) {
    let [t0, t1, t2, t3, t4] = taps;
    let [r0, r1, r2, r3, r4] = rows;
    for e in 0..out.len() {
        let acc = t0.mul_add(r0[e], t1 * r1[e]);
        let acc = t2.mul_add(r2[e], acc);
        let acc = t3.mul_add(r3[e], acc);
        out[e] = t4.mul_add(r4[e], acc);
    }
}

/// `row[e] *= d` — the IPK forward-sweep seed row.
#[inline]
pub fn scale_row<T: Scalar>(row: &mut [T], d: T) {
    #[cfg(target_arch = "x86_64")]
    if enabled() {
        if let Some(row) = cast_mut::<T, f64>(row) {
            // SAFETY: `enabled()` verified AVX2+FMA at runtime.
            unsafe { x86::scale_row_f64(row, d.to_f64()) };
            return;
        }
        if let Some(row) = cast_mut::<T, f32>(row) {
            // SAFETY: `enabled()` verified AVX2+FMA at runtime.
            unsafe { x86::scale_row_f32(row, d.to_f64() as f32) };
            return;
        }
    }
    scale_row_scalar(row, d);
}

/// Scalar reference for [`scale_row`].
#[inline]
pub fn scale_row_scalar<T: Scalar>(row: &mut [T], d: T) {
    for v in row.iter_mut() {
        let scaled = *v * d;
        *v = scaled;
    }
}

/// IPK forward sweep: `cur[e] = fma(-s, prev[e], cur[e]) * d`.
#[inline]
pub fn sweep_fwd_row<T: Scalar>(prev: &[T], cur: &mut [T], s: T, d: T) {
    #[cfg(target_arch = "x86_64")]
    if enabled() {
        if let (Some(prev), Some(cur)) = (cast::<T, f64>(prev), cast_mut::<T, f64>(cur)) {
            // SAFETY: `enabled()` verified AVX2+FMA at runtime.
            unsafe { x86::sweep_fwd_row_f64(prev, cur, s.to_f64(), d.to_f64()) };
            return;
        }
        if let (Some(prev), Some(cur)) = (cast::<T, f32>(prev), cast_mut::<T, f32>(cur)) {
            // SAFETY: `enabled()` verified AVX2+FMA at runtime.
            unsafe { x86::sweep_fwd_row_f32(prev, cur, s.to_f64() as f32, d.to_f64() as f32) };
            return;
        }
    }
    sweep_fwd_row_scalar(prev, cur, s, d);
}

/// Scalar reference for [`sweep_fwd_row`].
#[inline]
pub fn sweep_fwd_row_scalar<T: Scalar>(prev: &[T], cur: &mut [T], s: T, d: T) {
    for e in 0..cur.len() {
        cur[e] = ((-s).mul_add(prev[e], cur[e])) * d;
    }
}

/// IPK backward sweep: `cur[e] = fma(-c, next[e], cur[e])`.
#[inline]
pub fn sweep_bwd_row<T: Scalar>(next: &[T], cur: &mut [T], c: T) {
    #[cfg(target_arch = "x86_64")]
    if enabled() {
        if let (Some(next), Some(cur)) = (cast::<T, f64>(next), cast_mut::<T, f64>(cur)) {
            // SAFETY: `enabled()` verified AVX2+FMA at runtime.
            unsafe { x86::sweep_bwd_row_f64(next, cur, c.to_f64()) };
            return;
        }
        if let (Some(next), Some(cur)) = (cast::<T, f32>(next), cast_mut::<T, f32>(cur)) {
            // SAFETY: `enabled()` verified AVX2+FMA at runtime.
            unsafe { x86::sweep_bwd_row_f32(next, cur, c.to_f64() as f32) };
            return;
        }
    }
    sweep_bwd_row_scalar(next, cur, c);
}

/// Scalar reference for [`sweep_bwd_row`].
#[inline]
pub fn sweep_bwd_row_scalar<T: Scalar>(next: &[T], cur: &mut [T], c: T) {
    for e in 0..cur.len() {
        cur[e] = (-c).mul_add(next[e], cur[e]);
    }
}

/// `dst[e] = fma(sign, src[e], dst[e])` — scaled accumulate onto even
/// rows (temporal recombination).
#[inline]
pub fn axpy_row<T: Scalar>(dst: &mut [T], src: &[T], sign: T) {
    #[cfg(target_arch = "x86_64")]
    if enabled() {
        if let (Some(dst), Some(src)) = (cast_mut::<T, f64>(dst), cast::<T, f64>(src)) {
            // SAFETY: `enabled()` verified AVX2+FMA at runtime.
            unsafe { x86::axpy_row_f64(dst, src, sign.to_f64()) };
            return;
        }
        if let (Some(dst), Some(src)) = (cast_mut::<T, f32>(dst), cast::<T, f32>(src)) {
            // SAFETY: `enabled()` verified AVX2+FMA at runtime.
            unsafe { x86::axpy_row_f32(dst, src, sign.to_f64() as f32) };
            return;
        }
    }
    axpy_row_scalar(dst, src, sign);
}

/// Scalar reference for [`axpy_row`].
#[inline]
pub fn axpy_row_scalar<T: Scalar>(dst: &mut [T], src: &[T], sign: T) {
    for e in 0..dst.len() {
        dst[e] = sign.mul_add(src[e], dst[e]);
    }
}

/// Fused last-axis upsample + apply for one line: `b` (fine, `2a+1`)
/// accumulates `sign ×` the interpolant of `s` (coarse, `a+1`) with
/// per-interval ratios `r` (`a`). `tmp` is caller-provided scratch of at
/// least `a` elements so batched callers allocate once per task.
///
/// Fast path (`sign == ±1`, which covers decompose and recompose): the
/// interpolants are computed with [`interp_row_vr`] and applied with plain
/// `+=`/`-=` — bit-identical to the scalar `fma(±1, x, y)` because an fma
/// by `±1` rounds `y ± x` exactly once, which is what `+`/`-` compute.
/// Any other `sign` falls back to the scalar reference.
#[inline]
pub fn upsample_apply_row<T: Scalar>(s: &[T], r: &[T], b: &mut [T], sign: T, tmp: &mut [T]) {
    let a = r.len();
    debug_assert_eq!(s.len(), a + 1);
    debug_assert_eq!(b.len(), 2 * a + 1);
    debug_assert!(tmp.len() >= a);
    if !(sign == T::ONE || sign == -T::ONE) {
        upsample_apply_row_scalar(s, r, b, sign);
        return;
    }
    let tmp = &mut tmp[..a];
    interp_row_vr(&s[..a], &s[1..], r, tmp);
    if sign == T::ONE {
        for i in 0..a {
            b[2 * i] += s[i];
            b[2 * i + 1] += tmp[i];
        }
        b[2 * a] += s[a];
    } else {
        for i in 0..a {
            b[2 * i] -= s[i];
            b[2 * i + 1] -= tmp[i];
        }
        b[2 * a] -= s[a];
    }
}

/// Scalar reference for [`upsample_apply_row`].
#[inline]
pub fn upsample_apply_row_scalar<T: Scalar>(s: &[T], r: &[T], b: &mut [T], sign: T) {
    let a = r.len();
    for i in 0..a {
        b[2 * i] = sign.mul_add(s[i], b[2 * i]);
        let interp = r[i].mul_add(s[i + 1], (-r[i]).mul_add(s[i], s[i]));
        b[2 * i + 1] = sign.mul_add(interp, b[2 * i + 1]);
    }
    b[2 * a] = sign.mul_add(s[a], b[2 * a]);
}

/// The AVX2+FMA row bodies. Each function keeps the scalar op sequence
/// per lane — broadcast the constants, `loadu`/`fmadd`/`storeu` over full
/// vectors, then a scalar tail identical to the `_scalar` twin — so every
/// body is bit-identical to its dispatcher's fallback path.
#[cfg(target_arch = "x86_64")]
mod x86 {
    use core::arch::x86_64::*;

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn interp_row_f64(lo: &[f64], hi: &[f64], r: f64, out: &mut [f64]) {
        let n = out.len();
        let rv = _mm256_set1_pd(r);
        let nrv = _mm256_set1_pd(-r);
        let mut i = 0;
        while i + 4 <= n {
            let lov = _mm256_loadu_pd(lo.as_ptr().add(i));
            let hiv = _mm256_loadu_pd(hi.as_ptr().add(i));
            let inner = _mm256_fmadd_pd(nrv, lov, lov);
            _mm256_storeu_pd(out.as_mut_ptr().add(i), _mm256_fmadd_pd(rv, hiv, inner));
            i += 4;
        }
        while i < n {
            out[i] = r.mul_add(hi[i], (-r).mul_add(lo[i], lo[i]));
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn interp_row_f32(lo: &[f32], hi: &[f32], r: f32, out: &mut [f32]) {
        let n = out.len();
        let rv = _mm256_set1_ps(r);
        let nrv = _mm256_set1_ps(-r);
        let mut i = 0;
        while i + 8 <= n {
            let lov = _mm256_loadu_ps(lo.as_ptr().add(i));
            let hiv = _mm256_loadu_ps(hi.as_ptr().add(i));
            let inner = _mm256_fmadd_ps(nrv, lov, lov);
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_fmadd_ps(rv, hiv, inner));
            i += 8;
        }
        while i < n {
            out[i] = r.mul_add(hi[i], (-r).mul_add(lo[i], lo[i]));
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn interp_row_vr_f64(lo: &[f64], hi: &[f64], r: &[f64], out: &mut [f64]) {
        let n = out.len();
        let zero = _mm256_setzero_pd();
        let mut i = 0;
        while i + 4 <= n {
            let lov = _mm256_loadu_pd(lo.as_ptr().add(i));
            let hiv = _mm256_loadu_pd(hi.as_ptr().add(i));
            let rv = _mm256_loadu_pd(r.as_ptr().add(i));
            let nrv = _mm256_sub_pd(zero, rv);
            let inner = _mm256_fmadd_pd(nrv, lov, lov);
            _mm256_storeu_pd(out.as_mut_ptr().add(i), _mm256_fmadd_pd(rv, hiv, inner));
            i += 4;
        }
        while i < n {
            out[i] = r[i].mul_add(hi[i], (-r[i]).mul_add(lo[i], lo[i]));
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn interp_row_vr_f32(lo: &[f32], hi: &[f32], r: &[f32], out: &mut [f32]) {
        let n = out.len();
        let zero = _mm256_setzero_ps();
        let mut i = 0;
        while i + 8 <= n {
            let lov = _mm256_loadu_ps(lo.as_ptr().add(i));
            let hiv = _mm256_loadu_ps(hi.as_ptr().add(i));
            let rv = _mm256_loadu_ps(r.as_ptr().add(i));
            let nrv = _mm256_sub_ps(zero, rv);
            let inner = _mm256_fmadd_ps(nrv, lov, lov);
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_fmadd_ps(rv, hiv, inner));
            i += 8;
        }
        while i < n {
            out[i] = r[i].mul_add(hi[i], (-r[i]).mul_add(lo[i], lo[i]));
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn interp_sub_row_f64(lo: &[f64], hi: &[f64], r: f64, odd: &mut [f64]) {
        let n = odd.len();
        let rv = _mm256_set1_pd(r);
        let nrv = _mm256_set1_pd(-r);
        let mut i = 0;
        while i + 4 <= n {
            let lov = _mm256_loadu_pd(lo.as_ptr().add(i));
            let hiv = _mm256_loadu_pd(hi.as_ptr().add(i));
            let ov = _mm256_loadu_pd(odd.as_ptr().add(i));
            let interp = _mm256_fmadd_pd(rv, hiv, _mm256_fmadd_pd(nrv, lov, lov));
            _mm256_storeu_pd(odd.as_mut_ptr().add(i), _mm256_sub_pd(ov, interp));
            i += 4;
        }
        while i < n {
            let interp = r.mul_add(hi[i], (-r).mul_add(lo[i], lo[i]));
            odd[i] -= interp;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn interp_sub_row_f32(lo: &[f32], hi: &[f32], r: f32, odd: &mut [f32]) {
        let n = odd.len();
        let rv = _mm256_set1_ps(r);
        let nrv = _mm256_set1_ps(-r);
        let mut i = 0;
        while i + 8 <= n {
            let lov = _mm256_loadu_ps(lo.as_ptr().add(i));
            let hiv = _mm256_loadu_ps(hi.as_ptr().add(i));
            let ov = _mm256_loadu_ps(odd.as_ptr().add(i));
            let interp = _mm256_fmadd_ps(rv, hiv, _mm256_fmadd_ps(nrv, lov, lov));
            _mm256_storeu_ps(odd.as_mut_ptr().add(i), _mm256_sub_ps(ov, interp));
            i += 8;
        }
        while i < n {
            let interp = r.mul_add(hi[i], (-r).mul_add(lo[i], lo[i]));
            odd[i] -= interp;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn interp_add_row_f64(lo: &[f64], hi: &[f64], r: f64, odd: &mut [f64]) {
        let n = odd.len();
        let rv = _mm256_set1_pd(r);
        let nrv = _mm256_set1_pd(-r);
        let mut i = 0;
        while i + 4 <= n {
            let lov = _mm256_loadu_pd(lo.as_ptr().add(i));
            let hiv = _mm256_loadu_pd(hi.as_ptr().add(i));
            let ov = _mm256_loadu_pd(odd.as_ptr().add(i));
            let interp = _mm256_fmadd_pd(rv, hiv, _mm256_fmadd_pd(nrv, lov, lov));
            _mm256_storeu_pd(odd.as_mut_ptr().add(i), _mm256_add_pd(ov, interp));
            i += 4;
        }
        while i < n {
            let interp = r.mul_add(hi[i], (-r).mul_add(lo[i], lo[i]));
            odd[i] += interp;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn interp_add_row_f32(lo: &[f32], hi: &[f32], r: f32, odd: &mut [f32]) {
        let n = odd.len();
        let rv = _mm256_set1_ps(r);
        let nrv = _mm256_set1_ps(-r);
        let mut i = 0;
        while i + 8 <= n {
            let lov = _mm256_loadu_ps(lo.as_ptr().add(i));
            let hiv = _mm256_loadu_ps(hi.as_ptr().add(i));
            let ov = _mm256_loadu_ps(odd.as_ptr().add(i));
            let interp = _mm256_fmadd_ps(rv, hiv, _mm256_fmadd_ps(nrv, lov, lov));
            _mm256_storeu_ps(odd.as_mut_ptr().add(i), _mm256_add_ps(ov, interp));
            i += 8;
        }
        while i < n {
            let interp = r.mul_add(hi[i], (-r).mul_add(lo[i], lo[i]));
            odd[i] += interp;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn five_tap_row_f64(t: [f64; 5], rows: [&[f64]; 5], out: &mut [f64]) {
        let n = out.len();
        let [r0, r1, r2, r3, r4] = rows;
        let t0v = _mm256_set1_pd(t[0]);
        let t1v = _mm256_set1_pd(t[1]);
        let t2v = _mm256_set1_pd(t[2]);
        let t3v = _mm256_set1_pd(t[3]);
        let t4v = _mm256_set1_pd(t[4]);
        let mut i = 0;
        while i + 4 <= n {
            let v0 = _mm256_loadu_pd(r0.as_ptr().add(i));
            let v1 = _mm256_loadu_pd(r1.as_ptr().add(i));
            let v2 = _mm256_loadu_pd(r2.as_ptr().add(i));
            let v3 = _mm256_loadu_pd(r3.as_ptr().add(i));
            let v4 = _mm256_loadu_pd(r4.as_ptr().add(i));
            let acc = _mm256_fmadd_pd(t0v, v0, _mm256_mul_pd(t1v, v1));
            let acc = _mm256_fmadd_pd(t2v, v2, acc);
            let acc = _mm256_fmadd_pd(t3v, v3, acc);
            _mm256_storeu_pd(out.as_mut_ptr().add(i), _mm256_fmadd_pd(t4v, v4, acc));
            i += 4;
        }
        while i < n {
            let acc = t[0].mul_add(r0[i], t[1] * r1[i]);
            let acc = t[2].mul_add(r2[i], acc);
            let acc = t[3].mul_add(r3[i], acc);
            out[i] = t[4].mul_add(r4[i], acc);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn five_tap_row_f32(t: [f32; 5], rows: [&[f32]; 5], out: &mut [f32]) {
        let n = out.len();
        let [r0, r1, r2, r3, r4] = rows;
        let t0v = _mm256_set1_ps(t[0]);
        let t1v = _mm256_set1_ps(t[1]);
        let t2v = _mm256_set1_ps(t[2]);
        let t3v = _mm256_set1_ps(t[3]);
        let t4v = _mm256_set1_ps(t[4]);
        let mut i = 0;
        while i + 8 <= n {
            let v0 = _mm256_loadu_ps(r0.as_ptr().add(i));
            let v1 = _mm256_loadu_ps(r1.as_ptr().add(i));
            let v2 = _mm256_loadu_ps(r2.as_ptr().add(i));
            let v3 = _mm256_loadu_ps(r3.as_ptr().add(i));
            let v4 = _mm256_loadu_ps(r4.as_ptr().add(i));
            let acc = _mm256_fmadd_ps(t0v, v0, _mm256_mul_ps(t1v, v1));
            let acc = _mm256_fmadd_ps(t2v, v2, acc);
            let acc = _mm256_fmadd_ps(t3v, v3, acc);
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_fmadd_ps(t4v, v4, acc));
            i += 8;
        }
        while i < n {
            let acc = t[0].mul_add(r0[i], t[1] * r1[i]);
            let acc = t[2].mul_add(r2[i], acc);
            let acc = t[3].mul_add(r3[i], acc);
            out[i] = t[4].mul_add(r4[i], acc);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn scale_row_f64(row: &mut [f64], d: f64) {
        let n = row.len();
        let dv = _mm256_set1_pd(d);
        let mut i = 0;
        while i + 4 <= n {
            let v = _mm256_loadu_pd(row.as_ptr().add(i));
            _mm256_storeu_pd(row.as_mut_ptr().add(i), _mm256_mul_pd(v, dv));
            i += 4;
        }
        while i < n {
            row[i] *= d;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn scale_row_f32(row: &mut [f32], d: f32) {
        let n = row.len();
        let dv = _mm256_set1_ps(d);
        let mut i = 0;
        while i + 8 <= n {
            let v = _mm256_loadu_ps(row.as_ptr().add(i));
            _mm256_storeu_ps(row.as_mut_ptr().add(i), _mm256_mul_ps(v, dv));
            i += 8;
        }
        while i < n {
            row[i] *= d;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn sweep_fwd_row_f64(prev: &[f64], cur: &mut [f64], s: f64, d: f64) {
        let n = cur.len();
        let nsv = _mm256_set1_pd(-s);
        let dv = _mm256_set1_pd(d);
        let mut i = 0;
        while i + 4 <= n {
            let pv = _mm256_loadu_pd(prev.as_ptr().add(i));
            let cv = _mm256_loadu_pd(cur.as_ptr().add(i));
            let v = _mm256_mul_pd(_mm256_fmadd_pd(nsv, pv, cv), dv);
            _mm256_storeu_pd(cur.as_mut_ptr().add(i), v);
            i += 4;
        }
        while i < n {
            cur[i] = ((-s).mul_add(prev[i], cur[i])) * d;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn sweep_fwd_row_f32(prev: &[f32], cur: &mut [f32], s: f32, d: f32) {
        let n = cur.len();
        let nsv = _mm256_set1_ps(-s);
        let dv = _mm256_set1_ps(d);
        let mut i = 0;
        while i + 8 <= n {
            let pv = _mm256_loadu_ps(prev.as_ptr().add(i));
            let cv = _mm256_loadu_ps(cur.as_ptr().add(i));
            let v = _mm256_mul_ps(_mm256_fmadd_ps(nsv, pv, cv), dv);
            _mm256_storeu_ps(cur.as_mut_ptr().add(i), v);
            i += 8;
        }
        while i < n {
            cur[i] = ((-s).mul_add(prev[i], cur[i])) * d;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn sweep_bwd_row_f64(next: &[f64], cur: &mut [f64], c: f64) {
        let n = cur.len();
        let ncv = _mm256_set1_pd(-c);
        let mut i = 0;
        while i + 4 <= n {
            let nv = _mm256_loadu_pd(next.as_ptr().add(i));
            let cv = _mm256_loadu_pd(cur.as_ptr().add(i));
            _mm256_storeu_pd(cur.as_mut_ptr().add(i), _mm256_fmadd_pd(ncv, nv, cv));
            i += 4;
        }
        while i < n {
            cur[i] = (-c).mul_add(next[i], cur[i]);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn sweep_bwd_row_f32(next: &[f32], cur: &mut [f32], c: f32) {
        let n = cur.len();
        let ncv = _mm256_set1_ps(-c);
        let mut i = 0;
        while i + 8 <= n {
            let nv = _mm256_loadu_ps(next.as_ptr().add(i));
            let cv = _mm256_loadu_ps(cur.as_ptr().add(i));
            _mm256_storeu_ps(cur.as_mut_ptr().add(i), _mm256_fmadd_ps(ncv, nv, cv));
            i += 8;
        }
        while i < n {
            cur[i] = (-c).mul_add(next[i], cur[i]);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn axpy_row_f64(dst: &mut [f64], src: &[f64], sign: f64) {
        let n = dst.len();
        let sv = _mm256_set1_pd(sign);
        let mut i = 0;
        while i + 4 <= n {
            let s = _mm256_loadu_pd(src.as_ptr().add(i));
            let d = _mm256_loadu_pd(dst.as_ptr().add(i));
            _mm256_storeu_pd(dst.as_mut_ptr().add(i), _mm256_fmadd_pd(sv, s, d));
            i += 4;
        }
        while i < n {
            dst[i] = sign.mul_add(src[i], dst[i]);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn axpy_row_f32(dst: &mut [f32], src: &[f32], sign: f32) {
        let n = dst.len();
        let sv = _mm256_set1_ps(sign);
        let mut i = 0;
        while i + 8 <= n {
            let s = _mm256_loadu_ps(src.as_ptr().add(i));
            let d = _mm256_loadu_ps(dst.as_ptr().add(i));
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_fmadd_ps(sv, s, d));
            i += 8;
        }
        while i < n {
            dst[i] = sign.mul_add(src[i], dst[i]);
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn data(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    fn data32(n: usize, seed: u64) -> Vec<f32> {
        data(n, seed).into_iter().map(|v| v as f32).collect()
    }

    /// Lengths that cover empty rows, pure tails, exact vector widths,
    /// and mixed vector+tail runs for both lane counts.
    const LENS: [usize; 9] = [0, 1, 3, 4, 7, 8, 9, 31, 100];

    #[test]
    fn interp_rows_match_scalar() {
        for n in LENS {
            let (lo, hi) = (data(n, 1), data(n, 2));
            let r = 0.37;
            let (mut a, mut b) = (vec![0.0; n], vec![0.0; n]);
            interp_row(&lo, &hi, r, &mut a);
            interp_row_scalar(&lo, &hi, r, &mut b);
            assert_eq!(a, b, "interp_row n={n}");

            let rv = data(n, 3);
            interp_row_vr(&lo, &hi, &rv, &mut a);
            interp_row_vr_scalar(&lo, &hi, &rv, &mut b);
            assert_eq!(a, b, "interp_row_vr n={n}");

            let (mut a, mut b) = (data(n, 4), data(n, 4));
            interp_sub_row(&lo, &hi, r, &mut a);
            interp_sub_row_scalar(&lo, &hi, r, &mut b);
            assert_eq!(a, b, "interp_sub_row n={n}");
            interp_add_row(&lo, &hi, r, &mut a);
            interp_add_row_scalar(&lo, &hi, r, &mut b);
            assert_eq!(a, b, "interp_add_row n={n}");
        }
    }

    #[test]
    fn five_tap_and_sweeps_match_scalar() {
        for n in LENS {
            let rows: Vec<Vec<f64>> = (0..5).map(|s| data(n, 10 + s)).collect();
            let rr: [&[f64]; 5] = [&rows[0], &rows[1], &rows[2], &rows[3], &rows[4]];
            let taps = [0.1, -0.2, 0.7, 0.05, -0.4];
            let (mut a, mut b) = (vec![0.0; n], vec![0.0; n]);
            five_tap_row(taps, rr, &mut a);
            five_tap_row_scalar(taps, rr, &mut b);
            assert_eq!(a, b, "five_tap n={n}");

            let prev = data(n, 20);
            let (mut a, mut b) = (data(n, 21), data(n, 21));
            scale_row(&mut a, 0.83);
            scale_row_scalar(&mut b, 0.83);
            assert_eq!(a, b, "scale n={n}");
            sweep_fwd_row(&prev, &mut a, 0.31, 1.7);
            sweep_fwd_row_scalar(&prev, &mut b, 0.31, 1.7);
            assert_eq!(a, b, "fwd n={n}");
            sweep_bwd_row(&prev, &mut a, -0.11);
            sweep_bwd_row_scalar(&prev, &mut b, -0.11);
            assert_eq!(a, b, "bwd n={n}");

            let src = data(n, 22);
            axpy_row(&mut a, &src, -1.0);
            axpy_row_scalar(&mut b, &src, -1.0);
            assert_eq!(a, b, "axpy n={n}");
        }
    }

    #[test]
    fn f32_rows_match_scalar() {
        for n in LENS {
            let (lo, hi) = (data32(n, 1), data32(n, 2));
            let (mut a, mut b) = (vec![0.0f32; n], vec![0.0f32; n]);
            interp_row(&lo, &hi, 0.37f32, &mut a);
            interp_row_scalar(&lo, &hi, 0.37f32, &mut b);
            assert_eq!(a, b, "interp_row f32 n={n}");

            let prev = data32(n, 5);
            let (mut a, mut b) = (data32(n, 6), data32(n, 6));
            sweep_fwd_row(&prev, &mut a, 0.31f32, 1.7f32);
            sweep_fwd_row_scalar(&prev, &mut b, 0.31f32, 1.7f32);
            assert_eq!(a, b, "fwd f32 n={n}");
        }
    }

    #[test]
    fn upsample_apply_row_matches_scalar() {
        for a_len in [1usize, 2, 3, 8, 16, 33] {
            let s = data(a_len + 1, 30);
            let r = data(a_len, 31).iter().map(|v| v.abs().min(0.9)).collect::<Vec<_>>();
            for sign in [1.0f64, -1.0] {
                let base = data(2 * a_len + 1, 32);
                let mut fast = base.clone();
                let mut slow = base.clone();
                let mut tmp = vec![0.0; a_len];
                upsample_apply_row(&s, &r, &mut fast, sign, &mut tmp);
                upsample_apply_row_scalar(&s, &r, &mut slow, sign);
                assert_eq!(fast, slow, "upsample_apply a={a_len} sign={sign}");
            }
        }
    }
}
