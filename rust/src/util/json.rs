//! Minimal JSON parser (offline environment: no serde_json available).
//!
//! Supports the full JSON grammar minus exotic number forms; enough for
//! `artifacts/manifest.json` and the config files this crate reads, and
//! unit-tested against tricky inputs. Emits a plain [`Value`] tree.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// `obj.key` as &str or error (for required manifest fields).
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key)
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow!("missing string field '{key}'"))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.get(key)
            .and_then(Value::as_usize)
            .ok_or_else(|| anyhow!("missing numeric field '{key}'"))
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Value> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        bail!("trailing garbage at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                c => bail!("expected ',' or '}}', got '{}' at byte {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(a));
                }
                c => bail!("expected ',' or ']', got '{}' at byte {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000C}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if &self.b[self.i..self.i.min(self.b.len()).max(self.i)] == b""
                                    || self.i + 6 > self.b.len()
                                    || self.b[self.i] != b'\\'
                                    || self.b[self.i + 1] != b'u'
                                {
                                    bail!("lone high surrogate");
                                }
                                let hex2 = std::str::from_utf8(&self.b[self.i + 2..self.i + 6])?;
                                let lo = u32::from_str_radix(hex2, 16)?;
                                self.i += 6;
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(c).ok_or_else(|| anyhow!("bad surrogate pair"))?
                            } else {
                                char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint"))?
                            };
                            s.push(ch);
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                _ => {
                    // copy UTF-8 continuation bytes verbatim
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(s.parse::<f64>().map_err(|_| {
            anyhow!("invalid number '{s}' at byte {start}")
        })?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Serialize a [`Value`] (used for reports the examples write).
pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(v, &mut s);
    s
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Value::Arr(a) => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(v, out);
            }
            out.push(']');
        }
        Value::Obj(m) => {
            out.push('{');
            for (i, (k, v)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(&Value::Str(k.clone()), out);
                out.push(':');
                write_value(v, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let v = parse(
            r#"{"format": "hlo-text", "variants": [{"name": "d", "shape": [9, 9], "nlevels": 3}]}"#,
        )
        .unwrap();
        assert_eq!(v.req_str("format").unwrap(), "hlo-text");
        let vars = v.get("variants").unwrap().as_arr().unwrap();
        assert_eq!(vars[0].req_usize("nlevels").unwrap(), 3);
        let shape: Vec<usize> = vars[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![9, 9]);
    }

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-1.5e3").unwrap(), Value::Num(-1500.0));
        assert_eq!(parse(r#""a\nb""#).unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(parse(r#""é""#).unwrap(), Value::Str("é".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), Value::Str("😀".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"[1, [2, {"a": []}], 3]"#).unwrap();
        assert_eq!(v.as_arr().unwrap().len(), 3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":null,"d":true}}"#;
        let v = parse(src).unwrap();
        assert_eq!(to_string(&v), src);
    }

    #[test]
    fn utf8_passthrough() {
        let v = parse(r#""héllo ✓""#).unwrap();
        assert_eq!(v, Value::Str("héllo ✓".into()));
    }
}
