//! Executor actor: a `Send + Clone` handle to a dedicated PJRT thread.
//!
//! PJRT client/executable handles are raw pointers (not `Send`), so the
//! engine lives on its own OS thread and the multi-threaded coordinator
//! talks to it over a channel. One actor per process is the normal
//! deployment (the CPU PJRT client runs its own intra-op thread pool); the
//! coordinator pipelines gather/scatter and compression around it.

use std::path::PathBuf;
use std::sync::mpsc;

use anyhow::{anyhow, Result};

use crate::grid::Tensor;
use crate::runtime::{Engine, VariantMeta, XlaScalar};

enum Request {
    RunF32 {
        name: String,
        shape: Vec<usize>,
        data: Vec<f32>,
        coords: Vec<Vec<f64>>,
        reply: mpsc::Sender<Result<Vec<f32>>>,
    },
    RunF64 {
        name: String,
        shape: Vec<usize>,
        data: Vec<f64>,
        coords: Vec<Vec<f64>>,
        reply: mpsc::Sender<Result<Vec<f64>>>,
    },
    Variants {
        reply: mpsc::Sender<Vec<VariantMeta>>,
    },
    Warm {
        name: String,
        reply: mpsc::Sender<Result<()>>,
    },
}

/// Cloneable, `Send` handle to the engine thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Request>,
}

impl EngineHandle {
    /// Spawn the engine thread (loads the manifest, compiles lazily).
    pub fn spawn(artifact_dir: PathBuf) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || {
                let engine = match Engine::load(&artifact_dir) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                for req in rx {
                    match req {
                        Request::RunF32 {
                            name,
                            shape,
                            data,
                            coords,
                            reply,
                        } => {
                            let t = Tensor::from_vec(&shape, data);
                            let r = engine.run::<f32>(&name, &t, &coords).map(|o| o.into_vec());
                            let _ = reply.send(r);
                        }
                        Request::RunF64 {
                            name,
                            shape,
                            data,
                            coords,
                            reply,
                        } => {
                            let t = Tensor::from_vec(&shape, data);
                            let r = engine.run::<f64>(&name, &t, &coords).map(|o| o.into_vec());
                            let _ = reply.send(r);
                        }
                        Request::Variants { reply } => {
                            let _ = reply.send(engine.manifest().variants.clone());
                        }
                        Request::Warm { name, reply } => {
                            let _ = reply.send(engine.warm(&name));
                        }
                    }
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))??;
        Ok(EngineHandle { tx })
    }

    /// List all artifact variants.
    pub fn variants(&self) -> Result<Vec<VariantMeta>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Variants { reply })
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine thread gone"))
    }

    /// Pre-compile a variant (amortize compile latency before serving).
    pub fn warm(&self, name: &str) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Warm {
                name: name.to_string(),
                reply,
            })
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine thread gone"))?
    }

    /// Execute a named variant synchronously.
    pub fn run<T: ActorDispatch>(
        &self,
        name: &str,
        u: &Tensor<T>,
        coords: &[Vec<f64>],
    ) -> Result<Tensor<T>> {
        let shape = u.shape().to_vec();
        let out = T::dispatch_run(self, name, &shape, u.data(), coords)?;
        Ok(Tensor::from_vec(&shape, out))
    }

    /// Find a variant name for op/shape/dtype.
    pub fn find(&self, op: &str, shape: &[usize], dtype: &str) -> Result<Option<String>> {
        Ok(self
            .variants()?
            .into_iter()
            .find(|v| v.op == op && v.shape == shape && v.dtype == dtype)
            .map(|v| v.name))
    }
}

/// Monomorphic dispatch across the channel (the request enum is typed).
pub trait ActorDispatch: XlaScalar {
    fn dispatch_run(
        h: &EngineHandle,
        name: &str,
        shape: &[usize],
        data: &[Self],
        coords: &[Vec<f64>],
    ) -> Result<Vec<Self>>;
}

impl ActorDispatch for f32 {
    fn dispatch_run(
        h: &EngineHandle,
        name: &str,
        shape: &[usize],
        data: &[f32],
        coords: &[Vec<f64>],
    ) -> Result<Vec<f32>> {
        let (reply, rx) = mpsc::channel();
        h.tx.send(Request::RunF32 {
            name: name.to_string(),
            shape: shape.to_vec(),
            data: data.to_vec(),
            coords: coords.to_vec(),
            reply,
        })
        .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine thread gone"))?
    }
}

impl ActorDispatch for f64 {
    fn dispatch_run(
        h: &EngineHandle,
        name: &str,
        shape: &[usize],
        data: &[f64],
        coords: &[Vec<f64>],
    ) -> Result<Vec<f64>> {
        let (reply, rx) = mpsc::channel();
        h.tx.send(Request::RunF64 {
            name: name.to_string(),
            shape: shape.to_vec(),
            data: data.to_vec(),
            coords: coords.to_vec(),
            reply,
        })
        .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine thread gone"))?
    }
}
