//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! The Python side (`python/compile/aot.py`) lowers every model variant to
//! HLO *text* once at build time; this module is the only bridge between
//! the Rust coordinator and those artifacts. Interchange is text because
//! jax ≥ 0.5 emits `HloModuleProto`s with 64-bit instruction ids that the
//! bundled xla_extension 0.5.1 rejects — the text parser reassigns ids.
//!
//! * [`manifest`] — the machine-readable artifact registry.
//! * [`Engine`] — PJRT CPU client + lazily-compiled executable cache.
//! * [`actor`] — a dedicated executor thread exposing a `Send` handle
//!   (PJRT handles are not `Send`, so the coordinator talks to the engine
//!   through a channel).
//!
//! The PJRT path needs the `xla` crate (and its vendored xla_extension
//! C++ build), which offline environments don't have, so it is gated
//! behind the `pjrt` cargo feature. Without the feature a stub [`Engine`]
//! with the same API compiles instead: `Engine::load` fails with a clear
//! error and every caller (CLI `pjrt-check`, the quickstart example, the
//! coordinator's `Backend::Pjrt`) degrades gracefully.

pub mod actor;
pub mod manifest;

pub use actor::EngineHandle;
pub use manifest::{Manifest, VariantMeta};

use std::path::PathBuf;

use crate::util::Scalar;

/// Scalars that can cross the PJRT literal boundary.
#[cfg(feature = "pjrt")]
pub trait XlaScalar: Scalar + xla::NativeType + xla::ArrayElement {
    /// dtype string used in artifact names/manifest ("float32"/"float64").
    const DTYPE: &'static str;
}

/// Scalars that can cross the PJRT literal boundary (stub bound — the
/// `pjrt` feature adds the `xla` literal traits).
#[cfg(not(feature = "pjrt"))]
pub trait XlaScalar: Scalar {
    /// dtype string used in artifact names/manifest ("float32"/"float64").
    const DTYPE: &'static str;
}

impl XlaScalar for f32 {
    const DTYPE: &'static str = "float32";
}
impl XlaScalar for f64 {
    const DTYPE: &'static str = "float64";
}

#[cfg(feature = "pjrt")]
mod engine_impl {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    use anyhow::{anyhow, Context, Result};

    use super::{Manifest, VariantMeta, XlaScalar};
    use crate::grid::Tensor;

    /// PJRT engine: owns the client and a name → compiled-executable cache.
    ///
    /// Not `Send` (PJRT handles are raw pointers); wrap in
    /// [`super::EngineHandle`] for use from async/multi-threaded
    /// coordinators.
    pub struct Engine {
        client: xla::PjRtClient,
        manifest: Manifest,
        dir: PathBuf,
        cache: std::cell::RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
    }

    impl Engine {
        /// Load the artifact registry from a directory containing
        /// `manifest.json` (default: `artifacts/` next to the binary's cwd).
        pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
            let dir = dir.as_ref().to_path_buf();
            let manifest = Manifest::load(dir.join("manifest.json"))
                .with_context(|| format!("loading manifest from {}", dir.display()))?;
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
            Ok(Engine {
                client,
                manifest,
                dir,
                cache: Default::default(),
            })
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Find the variant for an op/shape/dtype triple.
        pub fn find(&self, op: &str, shape: &[usize], dtype: &str) -> Option<&VariantMeta> {
            self.manifest
                .variants
                .iter()
                .find(|v| v.op == op && v.shape == shape && v.dtype == dtype)
        }

        /// Compile (or fetch from cache) the named variant.
        pub fn executable(&self, name: &str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
            if let Some(e) = self.cache.borrow().get(name) {
                return Ok(e.clone());
            }
            let meta = self
                .manifest
                .variants
                .iter()
                .find(|v| v.name == name)
                .ok_or_else(|| anyhow!("unknown artifact variant {name}"))?;
            let path = self.dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            let exe = std::rc::Rc::new(exe);
            self.cache.borrow_mut().insert(name.to_string(), exe.clone());
            Ok(exe)
        }

        /// Pre-compile a variant (amortizes compile latency before serving).
        pub fn warm(&self, name: &str) -> Result<()> {
            self.executable(name).map(|_| ())
        }

        /// Execute a refactoring variant: inputs are the data tensor plus one
        /// coordinate vector per dimension; output is the same-shape tensor.
        pub fn run<T: XlaScalar>(
            &self,
            name: &str,
            u: &Tensor<T>,
            coords: &[Vec<f64>],
        ) -> Result<Tensor<T>> {
            let exe = self.executable(name)?;
            let shape: Vec<i64> = u.shape().iter().map(|&n| n as i64).collect();
            let mut args: Vec<xla::Literal> = Vec::with_capacity(1 + coords.len());
            args.push(
                xla::Literal::vec1(u.data())
                    .reshape(&shape)
                    .map_err(|e| anyhow!("reshape input: {e:?}"))?,
            );
            for c in coords {
                let cv: Vec<T> = c.iter().map(|&x| T::from_f64(x)).collect();
                args.push(xla::Literal::vec1(&cv));
            }
            let result = exe
                .execute::<xla::Literal>(&args)
                .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch result: {e:?}"))?;
            // aot.py lowers with return_tuple=True -> 1-tuple
            let out = lit.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
            let data: Vec<T> = out.to_vec().map_err(|e| anyhow!("to_vec: {e:?}"))?;
            Ok(Tensor::from_vec(u.shape(), data))
        }

        /// Convenience: run decompose for a shape/dtype if an artifact exists.
        pub fn decompose<T: XlaScalar>(
            &self,
            u: &Tensor<T>,
            coords: &[Vec<f64>],
        ) -> Result<Tensor<T>> {
            let op = if u.ndim() == 4 { "st_decompose" } else { "decompose" };
            let meta = self
                .find(op, u.shape(), T::DTYPE)
                .ok_or_else(|| anyhow!("no {op} artifact for shape {:?} {}", u.shape(), T::DTYPE))?;
            self.run(&meta.name.clone(), u, coords)
        }

        /// Convenience: run recompose for a shape/dtype if an artifact exists.
        pub fn recompose<T: XlaScalar>(
            &self,
            u: &Tensor<T>,
            coords: &[Vec<f64>],
        ) -> Result<Tensor<T>> {
            let op = if u.ndim() == 4 { "st_recompose" } else { "recompose" };
            let meta = self
                .find(op, u.shape(), T::DTYPE)
                .ok_or_else(|| anyhow!("no {op} artifact for shape {:?} {}", u.shape(), T::DTYPE))?;
            self.run(&meta.name.clone(), u, coords)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod engine_impl {
    use std::path::Path;

    use anyhow::{bail, Result};

    use super::{Manifest, VariantMeta, XlaScalar};
    use crate::grid::Tensor;

    /// Stub engine compiled when the `pjrt` feature is off: same API as
    /// the real one, but [`Engine::load`] always fails, so no other
    /// method is reachable at runtime.
    pub struct Engine {
        manifest: Manifest,
    }

    const DISABLED: &str = "PJRT runtime unavailable: this binary was built without the `pjrt` \
                            cargo feature (it needs the `xla` crate and a vendored xla_extension). \
                            The native core covers every operation; rebuild with `--features pjrt` \
                            for artifact execution.";

    impl Engine {
        pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
            let _ = dir;
            bail!(DISABLED)
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn platform(&self) -> String {
            "disabled".into()
        }

        pub fn find(&self, op: &str, shape: &[usize], dtype: &str) -> Option<&VariantMeta> {
            self.manifest
                .variants
                .iter()
                .find(|v| v.op == op && v.shape == shape && v.dtype == dtype)
        }

        pub fn warm(&self, _name: &str) -> Result<()> {
            bail!(DISABLED)
        }

        pub fn run<T: XlaScalar>(
            &self,
            _name: &str,
            _u: &Tensor<T>,
            _coords: &[Vec<f64>],
        ) -> Result<Tensor<T>> {
            bail!(DISABLED)
        }

        pub fn decompose<T: XlaScalar>(
            &self,
            _u: &Tensor<T>,
            _coords: &[Vec<f64>],
        ) -> Result<Tensor<T>> {
            bail!(DISABLED)
        }

        pub fn recompose<T: XlaScalar>(
            &self,
            _u: &Tensor<T>,
            _coords: &[Vec<f64>],
        ) -> Result<Tensor<T>> {
            bail!(DISABLED)
        }
    }
}

pub use engine_impl::Engine;

/// Default artifact directory: `$MGR_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("MGR_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
