//! The artifact registry written by `python/compile/aot.py`.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{self, Value};

/// One AOT-lowered model variant.
#[derive(Clone, Debug, PartialEq)]
pub struct VariantMeta {
    pub name: String,
    /// "decompose" | "recompose" | "st_decompose" | "st_recompose"
    pub op: String,
    pub shape: Vec<usize>,
    /// "float32" | "float64"
    pub dtype: String,
    pub nlevels: usize,
    pub inputs: Vec<String>,
    pub file: String,
    pub sha256: String,
    pub hlo_bytes: usize,
}

/// `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub format: String,
    pub variants: Vec<VariantMeta>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let v = json::parse(text).context("parsing manifest.json")?;
        let format = v.req_str("format")?.to_string();
        anyhow::ensure!(format == "hlo-text", "unsupported artifact format {format}");
        let mut variants = Vec::new();
        for item in v
            .get("variants")
            .and_then(Value::as_arr)
            .context("manifest missing 'variants' array")?
        {
            let shape = item
                .get("shape")
                .and_then(Value::as_arr)
                .context("variant missing shape")?
                .iter()
                .map(|x| x.as_usize().context("non-numeric shape entry"))
                .collect::<Result<Vec<_>>>()?;
            let inputs = item
                .get("inputs")
                .and_then(Value::as_arr)
                .map(|a| {
                    a.iter()
                        .filter_map(|x| x.as_str().map(String::from))
                        .collect()
                })
                .unwrap_or_default();
            variants.push(VariantMeta {
                name: item.req_str("name")?.to_string(),
                op: item.req_str("op")?.to_string(),
                shape,
                dtype: item.req_str("dtype")?.to_string(),
                nlevels: item.req_usize("nlevels")?,
                inputs,
                file: item.req_str("file")?.to_string(),
                sha256: item
                    .get("sha256")
                    .and_then(Value::as_str)
                    .unwrap_or_default()
                    .to_string(),
                hlo_bytes: item
                    .get("hlo_bytes")
                    .and_then(Value::as_usize)
                    .unwrap_or_default(),
            });
        }
        Ok(Manifest { format, variants })
    }

    /// Variants for a given op, sorted by total element count.
    pub fn by_op(&self, op: &str) -> Vec<&VariantMeta> {
        let mut v: Vec<&VariantMeta> = self.variants.iter().filter(|v| v.op == op).collect();
        v.sort_by_key(|v| v.shape.iter().product::<usize>());
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_minimal() {
        let json = r#"{
            "format": "hlo-text",
            "variants": [{
                "name": "decompose_9x9_float32_l3",
                "op": "decompose",
                "shape": [9, 9],
                "dtype": "float32",
                "nlevels": 3,
                "inputs": ["u", "x0", "x1"],
                "file": "decompose_9x9_float32_l3.hlo.txt"
            }]
        }"#;
        let m = Manifest::parse(json).unwrap();
        assert_eq!(m.variants.len(), 1);
        assert_eq!(m.variants[0].shape, vec![9, 9]);
        assert_eq!(m.by_op("decompose").len(), 1);
        assert!(m.by_op("recompose").is_empty());
    }

    #[test]
    fn rejects_wrong_format() {
        let json = r#"{"format": "proto", "variants": []}"#;
        assert!(Manifest::parse(json).is_err());
    }

    #[test]
    fn loads_real_manifest_if_present() {
        // integration sanity: if `make artifacts` has run, the real
        // manifest must parse and every referenced file must exist
        let path = std::path::Path::new("artifacts/manifest.json");
        if path.exists() {
            let m = Manifest::load(path).unwrap();
            assert!(!m.variants.is_empty());
            for v in &m.variants {
                assert!(std::path::Path::new("artifacts").join(&v.file).exists());
            }
        }
    }
}
