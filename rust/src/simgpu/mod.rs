//! GPU performance substrate: analytic device + cluster models, plus the
//! host calibration pass that closes the loop on real execution.
//!
//! The paper's evaluation hardware (Summit nodes with 6×V100, an RTX 2080
//! Ti desktop, NVLink/X-Bus interconnects) is not available here, so —
//! per the substitution rule recorded in `DESIGN.md` — we model it
//! analytically. The paper itself argues (§3.2) that refactoring is
//! memory-bound and models kernel time purely from memory transactions;
//! the same models, parameterized by published bandwidths, reproduce the
//! *shape* of Figs 13–17. Correctness always runs on real compute (the
//! native core or the PJRT artifacts); only *wall-clock at Summit scale*
//! is simulated.
//!
//! * [`device`] — device specs (V100, RTX 2080 Ti, POWER9 core) and
//!   interconnects (NVLink, X-Bus, EDR InfiniBand), with typed
//!   validation ([`SpecError`]).
//! * [`perfmodel`] — §3.2 transaction-count models for GPK/LPK/IPK and the
//!   second-order "measured" simulator behind Table 2.
//! * [`autotune`] — heuristic auto-tuning: model-rank, prune to top-3,
//!   measure, pick (§3.2). [`prune_and_profile`] is the reusable loop.
//! * [`calibrate`] — the same prune-and-profile loop re-targeted at the
//!   *host*: short measured runs of the real kernels choose fork
//!   configurations for [`crate::util::par`], and a stream benchmark
//!   measures the roofline peak that benches normalize against.
//! * [`cluster`] — single-GPU / node / multi-node throughput roll-ups
//!   (Figs 14, 16, 17) including cooperative-parallel communication.

pub mod autotune;
pub mod calibrate;
pub mod cluster;
pub mod device;
pub mod perfmodel;

pub use autotune::{autotune, autotune_checked, prune_and_profile, AutotuneResult};
pub use calibrate::{calibrate, measure_peak_gbps, CalibrationReport, KernelCalibration};
pub use cluster::{ClusterModel, Parallelism};
pub use device::{DeviceSpec, Interconnect, SpecError};
pub use perfmodel::{BlockConfig, Kernel, PerfModel};
