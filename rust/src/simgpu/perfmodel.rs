//! §3.2 heuristic performance models for the three kernels (Table 2).
//!
//! The models count memory transactions only — the paper's argument is
//! that after the §3.1 optimizations the kernels are memory-bound, so
//! execution time ≈ (transactions × transaction size) / bandwidth. The
//! purpose is *ranking* thread-block configurations, not absolute
//! prediction: the auto-tuner (see [`crate::simgpu::autotune`]) prunes the
//! search space to the model's top-3 and measures those.
//!
//! [`PerfModel::measured_time`] is the stand-in for profiling on real
//! hardware: it layers the second-order effects the transaction model
//! ignores (occupancy limits, shared-memory residency, divergence and
//! fp64-throughput penalties) on top of the model, which is what makes
//! the model's top-1 *not* always the actual best — the phenomenon
//! Table 2 highlights in red and the reason top-3 pruning is needed.

use crate::simgpu::device::DeviceSpec;

/// Thread-block configuration `(Bx, By, Bz)` — `Bx` is the contiguous
/// (coalescing) dimension.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct BlockConfig {
    pub bx: usize,
    pub by: usize,
    pub bz: usize,
}

impl BlockConfig {
    pub const fn new(bx: usize, by: usize, bz: usize) -> Self {
        BlockConfig { bx, by, bz }
    }

    pub fn threads(&self) -> usize {
        self.bx * self.by * self.bz
    }
}

impl std::fmt::Display for BlockConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "({},{},{})", self.bz, self.by, self.bx)
    }
}

/// The seven configurations evaluated in Table 2 (listed `(Bz, By, Bx)`
/// in the paper; stored `(Bx, By, Bz)` here).
pub const TABLE2_CONFIGS: [BlockConfig; 7] = [
    BlockConfig::new(2, 2, 2),
    BlockConfig::new(4, 4, 4),
    BlockConfig::new(8, 4, 4),
    BlockConfig::new(16, 4, 4),
    BlockConfig::new(32, 4, 4),
    BlockConfig::new(64, 2, 2),
    BlockConfig::new(128, 2, 2),
];

/// Which processing kernel a prediction is for.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Kernel {
    Gpk,
    Lpk,
    Ipk,
}

impl Kernel {
    pub const ALL: [Kernel; 3] = [Kernel::Gpk, Kernel::Lpk, Kernel::Ipk];

    pub fn name(&self) -> &'static str {
        match self {
            Kernel::Gpk => "GPK",
            Kernel::Lpk => "LPK",
            Kernel::Ipk => "IPK",
        }
    }
}

/// Performance model for one device / input size / precision.
#[derive(Clone, Debug)]
pub struct PerfModel {
    pub device: DeviceSpec,
    /// Per-dimension input size `N` (cubic input, as in the paper).
    pub n: usize,
    /// Bytes per element (the paper's `L`).
    pub elem_bytes: usize,
}

impl PerfModel {
    pub fn new(device: DeviceSpec, n: usize, elem_bytes: usize) -> Self {
        PerfModel {
            device,
            n,
            elem_bytes,
        }
    }

    /// Elements per memory transaction (`S / L`).
    fn spl(&self) -> f64 {
        self.device.transaction_bytes as f64 / self.elem_bytes as f64
    }

    /// §3.2 estimated execution time, seconds.
    pub fn model_time(&self, kernel: Kernel, cfg: BlockConfig) -> f64 {
        let n = self.n as f64;
        let (bx, by, bz) = (cfg.bx as f64, cfg.by as f64, cfg.bz as f64);
        let spl = self.spl();
        let l2 = 2.0 * self.elem_bytes as f64;
        let bw = self.device.mem_bw;
        let blocks = (n / bx).floor() * (n / by).floor() * (n / bz).floor();
        match kernel {
            Kernel::Gpk => {
                // halo'd tile loads: ceil((Bx+1)/(S/L))·(S/L)·(By+1)·(Bz+1)
                let tx = ((bx + 1.0) / spl).ceil() * spl * (by + 1.0) * (bz + 1.0);
                tx * blocks * l2 / bw
            }
            Kernel::Lpk => {
                // tile + two ghost columns along the processed dim
                let tx = ((bx / spl).ceil() * spl + 2.0 * spl) * by * bz;
                tx * blocks * l2 / bw
            }
            Kernel::Ipk => {
                // per vector batch: ghost fetch + segmented sweep over N
                let g = spl; // ghost sized to one transaction (paper's G)
                let per_vec = (g / spl).ceil() * spl + (bx / spl).ceil() * spl * (n / bx).ceil();
                let batches = by * bz * (n / by).floor() * (n / bz).floor();
                per_vec * batches * l2 / bw
            }
        }
    }

    /// Shared-memory bytes one block of this kernel needs (tile + halo).
    pub fn shared_mem(&self, kernel: Kernel, cfg: BlockConfig) -> usize {
        let l = self.elem_bytes;
        match kernel {
            Kernel::Gpk => (cfg.bx + 1) * (cfg.by + 1) * (cfg.bz + 1) * l,
            Kernel::Lpk => (cfg.bx + 2 * self.spl() as usize) * cfg.by * cfg.bz * l,
            // IPK keeps main + 2 ghost + prefetch segments resident (Fig 7)
            Kernel::Ipk => 4 * cfg.bx * cfg.by * cfg.bz * l,
        }
    }

    /// Simulated *measured* time: the transaction model degraded by the
    /// second-order effects real profiling would see.
    pub fn measured_time(&self, kernel: Kernel, cfg: BlockConfig) -> f64 {
        let base = self.model_time(kernel, cfg);

        // -- occupancy: resident threads per SM limited by thread slots
        //    and shared memory; low-thread configs cannot cover latency.
        let threads = cfg.threads() as f64;
        let smem = self.shared_mem(kernel, cfg) as f64;
        let blocks_by_smem = (96.0 * 1024.0 / smem).floor().clamp(1.0, 32.0);
        let blocks_by_threads =
            (self.device.max_threads_per_sm as f64 / threads).floor().max(1.0);
        let resident = threads * blocks_by_smem.min(blocks_by_threads);
        // ~512 resident threads/SM saturate the memory pipeline
        let occupancy = (resident / 512.0).min(1.0);

        // -- divergence: blocks narrower than a 32-lane warp in the
        //    contiguous dimension split warps across rows (partial
        //    coalescing + masked lanes).
        let warp_eff = (cfg.bx as f64 / 32.0).min(1.0).max(0.25);
        let divergence = match kernel {
            Kernel::Gpk => warp_eff.sqrt(), // §3.1.1: interpolation-type branching
            Kernel::Lpk => warp_eff.sqrt().sqrt(),
            Kernel::Ipk => 1.0, // batched sweeps are divergence-free
        };

        // -- IPK serialization: the sweep's sequential segments leave a
        //    pipeline bubble proportional to segment count when the batch
        //    (By·Bz planes) is small.
        let ipk_bubble = if kernel == Kernel::Ipk {
            1.0 + 0.3 * (cfg.bx as f64 / 4.0).ln().max(0.0)
        } else {
            1.0
        };

        base / occupancy.max(0.05) / divergence * ipk_bubble
    }

    /// Rank configurations by a time function: returns rank per config
    /// (1 = fastest), aligned with the input order. NaN times rank
    /// deterministically last ([`f64::total_cmp`]) instead of panicking.
    pub fn rank_by(times: &[f64]) -> Vec<usize> {
        let mut order: Vec<usize> = (0..times.len()).collect();
        order.sort_by(|&a, &b| times[a].total_cmp(&times[b]));
        let mut ranks = vec![0usize; times.len()];
        for (rank, idx) in order.into_iter().enumerate() {
            ranks[idx] = rank + 1;
        }
        ranks
    }

    /// Model-predicted ranking of the Table-2 configurations.
    pub fn model_ranking(&self, kernel: Kernel) -> Vec<usize> {
        let times: Vec<f64> = TABLE2_CONFIGS
            .iter()
            .map(|&c| self.model_time(kernel, c))
            .collect();
        Self::rank_by(&times)
    }

    /// Simulated-measured ranking of the Table-2 configurations.
    pub fn measured_ranking(&self, kernel: Kernel) -> Vec<usize> {
        let times: Vec<f64> = TABLE2_CONFIGS
            .iter()
            .map(|&c| self.measured_time(kernel, c))
            .collect();
        Self::rank_by(&times)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> PerfModel {
        PerfModel::new(DeviceSpec::volta_v100(), 513, 4)
    }

    #[test]
    fn lpk_ranking_matches_paper_exactly() {
        // Table 2, LPK column: 7 6 5 4 3 2 1 (larger Bx strictly better)
        assert_eq!(model().model_ranking(Kernel::Lpk), vec![7, 6, 5, 4, 3, 2, 1]);
    }

    #[test]
    fn gpk_best_is_4_4_32() {
        // Table 2, GPK column rank 1 = (4,4,32)
        let ranks = model().model_ranking(Kernel::Gpk);
        assert_eq!(ranks[4], 1, "GPK best should be (4,4,32): {ranks:?}");
        assert_eq!(ranks[0], 7, "(2,2,2) is worst");
    }

    #[test]
    fn smallest_config_always_worst() {
        let m = model();
        for k in Kernel::ALL {
            assert_eq!(m.model_ranking(k)[0], 7, "{k:?}");
        }
    }

    #[test]
    fn measured_best_in_model_top3() {
        // the property that justifies top-3 pruning (§3.2)
        let m = model();
        for k in Kernel::ALL {
            let model_ranks = m.model_ranking(k);
            let measured = m.measured_ranking(k);
            let actual_best = measured.iter().position(|&r| r == 1).unwrap();
            assert!(
                model_ranks[actual_best] <= 3,
                "{k:?}: actual best {} has model rank {}",
                TABLE2_CONFIGS[actual_best],
                model_ranks[actual_best]
            );
        }
    }

    #[test]
    fn ipk_measured_prefers_moderate_segments() {
        // the Table-2 phenomenon: IPK's *measured* best is a small/mid
        // segment (pipeline-bubble effects), and large segments that the
        // transaction model likes fall behind
        let m = model();
        let measured = m.measured_ranking(Kernel::Ipk);
        let best = measured.iter().position(|&r| r == 1).unwrap();
        assert!(
            (1..=2).contains(&best),
            "IPK measured best should be (4,4,4) or (4,4,8): {measured:?}"
        );
        // the biggest segments are not the winners once second-order
        // effects apply
        assert!(measured[5] > 3 && measured[6] > 3, "{measured:?}");
    }

    #[test]
    fn double_precision_slower() {
        let m32 = model();
        let m64 = PerfModel::new(DeviceSpec::volta_v100(), 513, 8);
        let c = TABLE2_CONFIGS[4];
        for k in Kernel::ALL {
            assert!(m64.model_time(k, c) > m32.model_time(k, c));
        }
    }

    #[test]
    fn rank_by_basics() {
        assert_eq!(PerfModel::rank_by(&[3.0, 1.0, 2.0]), vec![3, 1, 2]);
    }

    #[test]
    fn rank_by_nan_sinks_last() {
        // regression: a NaN time used to panic the unwrap'd partial_cmp
        assert_eq!(PerfModel::rank_by(&[f64::NAN, 1.0, 2.0]), vec![3, 1, 2]);
        assert_eq!(PerfModel::rank_by(&[f64::NAN, f64::NAN]), vec![1, 2]);
    }
}
