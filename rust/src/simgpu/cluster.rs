//! Cluster-level throughput models (Figs 14, 16, 17).
//!
//! Roll-ups from the per-kernel efficiency profiles to single-GPU,
//! single-node multi-GPU (cooperative / embarrassing) and Summit-scale
//! aggregate refactoring throughput.
//!
//! ## Calibration
//!
//! Implementation profiles ([`ImplProfile`]) carry per-kernel memory
//! efficiencies. The OPT-family numbers are derived from the §3.2
//! transaction model (small halo/ceil overheads); the SOTA numbers are
//! those divided by the paper's measured Fig-13 kernel speedups, plus the
//! extra unfused passes the baseline performs. The resulting *end-to-end*
//! efficiencies land at ≈92% (OPT+AT+FMA+REO) and ≈10% (SOTA-GPU) of the
//! theoretical peak — the paper's Fig 16 numbers — which makes Figs 14/17
//! derived quantities, exactly as they are in the paper.

use crate::simgpu::device::{DeviceSpec, Interconnect};

/// Data-refactoring implementation variants evaluated in §4.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Impl {
    SotaCpu,
    SotaGpu,
    Opt,
    OptAt,
    OptAtFma,
    OptAtFmaReo,
}

impl Impl {
    pub const ALL: [Impl; 6] = [
        Impl::SotaCpu,
        Impl::SotaGpu,
        Impl::Opt,
        Impl::OptAt,
        Impl::OptAtFma,
        Impl::OptAtFmaReo,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Impl::SotaCpu => "SOTA-CPU",
            Impl::SotaGpu => "SOTA-GPU",
            Impl::Opt => "OPT",
            Impl::OptAt => "OPT+AT",
            Impl::OptAtFma => "OPT+AT+FMA",
            Impl::OptAtFmaReo => "OPT+AT+FMA+REO",
        }
    }
}

/// Per-kernel memory-efficiency profile of one implementation.
#[derive(Clone, Copy, Debug)]
pub struct ImplProfile {
    pub gpk_eff: f64,
    pub lpk_eff: f64,
    pub ipk_eff: f64,
    /// copy / apply passes
    pub aux_eff: f64,
    /// extra whole-data passes per level vs. the canonical count
    /// (unfused intermediates in the baseline)
    pub extra_passes: f64,
    /// multiplicative launch/sync overhead (CUDA streams, kernel launches)
    pub overhead: f64,
}

/// Canonical per-level pass weights (paper §4.4): 1 coefficient pass,
/// 1 copy-to-workspace, 5.25 correction passes (split LPK 3.25 / IPK 2.0),
/// 0.125 apply.
pub const PASS_COEF: f64 = 1.0;
pub const PASS_COPY: f64 = 1.0;
pub const PASS_LPK: f64 = 3.25;
pub const PASS_IPK: f64 = 2.0;
pub const PASS_APPLY: f64 = 0.125;

pub fn passes_per_level() -> f64 {
    PASS_COEF + PASS_COPY + PASS_LPK + PASS_IPK + PASS_APPLY
}

impl Impl {
    /// Calibrated efficiency profile (see module docs).
    pub fn profile(&self, _device: &DeviceSpec, _elem_bytes: usize) -> ImplProfile {
        match self {
            // SOTA kernel efficiencies = OPT's divided by the paper's
            // Fig-13 speedups (GPK 4.9x, LPK 6.3x, IPK 3.0x on Volta),
            // plus 3 unfused intermediate passes and stream overhead.
            Impl::SotaCpu | Impl::SotaGpu => ImplProfile {
                gpk_eff: 0.95 / 4.9,
                lpk_eff: 0.93 / 6.3,
                ipk_eff: 0.90 / 3.0,
                aux_eff: 0.90,
                extra_passes: 3.0,
                overhead: 0.78,
            },
            Impl::Opt => ImplProfile {
                gpk_eff: 0.80,
                lpk_eff: 0.78,
                ipk_eff: 0.62,
                aux_eff: 0.92,
                extra_passes: 0.0,
                overhead: 0.97,
            },
            Impl::OptAt => ImplProfile {
                gpk_eff: 0.90,
                lpk_eff: 0.88,
                ipk_eff: 0.78,
                aux_eff: 0.93,
                extra_passes: 0.0,
                overhead: 0.97,
            },
            Impl::OptAtFma => ImplProfile {
                gpk_eff: 0.93,
                lpk_eff: 0.91,
                ipk_eff: 0.86,
                aux_eff: 0.94,
                extra_passes: 0.0,
                overhead: 0.98,
            },
            Impl::OptAtFmaReo => ImplProfile {
                gpk_eff: 0.95,
                lpk_eff: 0.93,
                ipk_eff: 0.90,
                aux_eff: 0.95,
                extra_passes: 0.0,
                overhead: 0.98,
            },
        }
    }

    /// End-to-end fraction of the theoretical peak this implementation
    /// achieves (Fig 16's 10.4% vs 92.2% numbers).
    pub fn end_to_end_efficiency(&self, device: &DeviceSpec, elem_bytes: usize) -> f64 {
        let p = self.profile(device, elem_bytes);
        let canonical = passes_per_level();
        let weighted = PASS_COEF / p.gpk_eff
            + PASS_COPY / p.aux_eff
            + PASS_LPK / p.lpk_eff
            + PASS_IPK / p.ipk_eff
            + PASS_APPLY / p.aux_eff
            + p.extra_passes / p.aux_eff;
        let pre_f64 = canonical / weighted * p.overhead;
        // consumer-GPU fp64 wall applies to non-FMA variants (§3.5 / §4.3)
        let f64_wall = if elem_bytes == 8
            && device.fp64_flops < 1e12
            && matches!(self, Impl::SotaGpu | Impl::SotaCpu | Impl::Opt | Impl::OptAt)
        {
            0.62
        } else {
            1.0
        };
        pre_f64 * f64_wall
    }
}

/// Throughput model for a device / hierarchy combination.
#[derive(Clone, Debug)]
pub struct ClusterModel {
    pub device: DeviceSpec,
    /// Dimensionality of the refactored data (2^-d level shrink factor).
    pub ndim: usize,
    pub nlevels: usize,
    pub elem_bytes: usize,
}

/// Multi-GPU execution strategy (§3.6).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Parallelism {
    /// Independent partitions, no communication.
    Embarrassing,
    /// One refactoring shared by a GPU group (halo exchange + round-robin
    /// solver partitions).
    Cooperative {
        group_size: usize,
    },
}

impl ClusterModel {
    pub fn new(device: DeviceSpec, ndim: usize, nlevels: usize, elem_bytes: usize) -> Self {
        ClusterModel {
            device,
            ndim,
            nlevels,
            elem_bytes,
        }
    }

    /// Accumulated whole-data passes over all levels:
    /// `passes_per_level × Σ_{l=0..levels-1} 2^{-l·d}`.
    pub fn total_passes(&self) -> f64 {
        let shrink = 2f64.powi(-(self.ndim as i32));
        let geo: f64 = (0..self.nlevels).map(|l| shrink.powi(l as i32)).sum();
        passes_per_level() * geo
    }

    /// Theoretical peak refactoring throughput (bytes of input per second)
    /// — the paper's 49.8 GB/s (V100) / 32.0 GB/s (2080 Ti) numbers.
    pub fn theoretical_peak(&self) -> f64 {
        self.device.single_pass_bw() / self.total_passes()
    }

    /// Input-size occupancy factor: small inputs cannot fill the device
    /// (visible in Fig 16's ramp across 65³..513³).
    pub fn size_factor(&self, n_elems: usize) -> f64 {
        let full = 64.0 * 1024.0 * 1024.0; // ~256³ f32 saturates
        (n_elems as f64 / full).powf(0.25).min(1.0).max(0.35)
    }

    /// Single-device refactoring throughput for an implementation.
    pub fn single_device_throughput(&self, im: Impl, n_elems: usize) -> f64 {
        let eff = im.end_to_end_efficiency(&self.device, self.elem_bytes);
        self.theoretical_peak() * eff * self.size_factor(n_elems)
    }

    /// Cooperative-group throughput for `s` GPUs sharing one refactoring
    /// of `bytes_total` input (per §3.6: halo exchange overlapped for
    /// GPK/LPK, shifted round-robin for IPK).
    pub fn coop_group_throughput(
        &self,
        im: Impl,
        s: usize,
        bytes_total: f64,
        intra: Interconnect,
        needs_xbus: bool,
    ) -> f64 {
        assert!(s >= 1);
        let per_gpu_bytes = bytes_total / s as f64;
        let n_elems = (per_gpu_bytes / self.elem_bytes as f64) as usize;
        let single = self.single_device_throughput(im, n_elems);
        if s == 1 {
            return single;
        }
        let compute_time = per_gpu_bytes / single;

        // halo exchange per level: each partition surface is
        // (per-GPU volume)^(2/3) elements thick-1 per neighbor; two
        // exchanges per level (GPK + LPK), partially overlapped (we charge
        // the non-overlapped 30%).
        let elems_per_gpu = per_gpu_bytes / self.elem_bytes as f64;
        let surface = elems_per_gpu.powf(2.0 / 3.0) * self.elem_bytes as f64;
        let link = if needs_xbus {
            // X-Bus is shared by the two islands: effective per-GPU share
            Interconnect {
                bw: Interconnect::xbus().bw / s as f64,
                ..Interconnect::xbus()
            }
        } else {
            intra
        };
        // GPK/LPK halos overlap with core-region compute; only ~30% of
        // the transfer is exposed (§3.6.1). Over X-Bus nothing overlaps
        // well — the link is shared with CPU traffic.
        let overlap = if needs_xbus { 1.0 } else { 0.3 };
        let halo_time: f64 = (0..self.nlevels)
            .map(|l| {
                let lvl_surface = surface * 4f64.powi(-(l as i32)); // surface shrinks 4x/level (3D)
                2.0 * overlap * link.transfer_time(lvl_surface)
            })
            .sum::<f64>();

        // The correction sweeps redistribute partition state along the
        // solve dimension each level (~15% of the level's volume moves).
        let redistribution: f64 = (0..self.nlevels)
            .map(|l| {
                let lvl_bytes = 0.15 * per_gpu_bytes * 8f64.powi(-(l as i32));
                link.transfer_time(lvl_bytes)
            })
            .sum::<f64>();

        // IPK shifted round-robin keeps all GPUs busy but pays a pipeline
        // fill/drain bubble of (s-1)/segments; with ~16 segments:
        let ipk_fraction = PASS_IPK / passes_per_level();
        let bubble = 1.0 + ipk_fraction * (s as f64 - 1.0) / 16.0;

        let total_time = compute_time * bubble + halo_time + redistribution;
        bytes_total / total_time
    }

    /// Aggregate weak-scaling throughput (Fig 17): `nodes` Summit nodes,
    /// 6 GPUs or 42 CPU cores per node, 1 GB per device/core.
    pub fn weak_scaling(&self, im: Impl, nodes: usize, parallelism: Parallelism) -> f64 {
        let gb = 1e9f64;
        match im {
            Impl::SotaCpu => {
                // 42 POWER9 cores per node, embarrassingly parallel MPI
                let core = ClusterModel::new(
                    DeviceSpec::power9_core(),
                    self.ndim,
                    self.nlevels,
                    self.elem_bytes,
                );
                let per_core =
                    core.theoretical_peak() * 0.10 * core.size_factor((gb / 8.0) as usize);
                per_core * 42.0 * nodes as f64
            }
            _ => match parallelism {
                Parallelism::Embarrassing => {
                    let per_gpu = self.single_device_throughput(im, (gb / 8.0) as usize);
                    per_gpu * 6.0 * nodes as f64
                }
                Parallelism::Cooperative { group_size } => {
                    let groups_per_node = 6 / group_size;
                    let per_group = self.coop_group_throughput(
                        im,
                        group_size,
                        gb * group_size as f64,
                        Interconnect::nvlink(),
                        group_size > 3,
                    );
                    per_group * groups_per_node as f64 * nodes as f64
                }
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn volta_model() -> ClusterModel {
        // 3D data, 9 levels (513^3-like), double precision (Fig 17 setup)
        ClusterModel::new(DeviceSpec::volta_v100(), 3, 9, 8)
    }

    #[test]
    fn theoretical_peak_matches_paper() {
        // paper: 49.8 GB/s on Summit V100
        let peak = volta_model().theoretical_peak();
        assert!(
            (peak / 1e9 - 49.8).abs() < 5.0,
            "V100 peak {:.1} GB/s, paper says 49.8",
            peak / 1e9
        );
        // 2080 Ti: 32.0 GB/s
        let t = ClusterModel::new(DeviceSpec::turing_2080ti(), 3, 9, 4);
        assert!((t.theoretical_peak() / 1e9 - 32.0).abs() < 4.0);
    }

    #[test]
    fn efficiency_ends_match_paper() {
        let v = DeviceSpec::volta_v100();
        let sota = Impl::SotaGpu.end_to_end_efficiency(&v, 4);
        let opt = Impl::OptAtFmaReo.end_to_end_efficiency(&v, 4);
        assert!(sota < 0.15, "SOTA eff {sota} should be ~0.104");
        assert!(sota > 0.06);
        assert!(opt > 0.88, "OPT eff {opt} should be ~0.922");
        assert!(opt <= 1.0);
    }

    #[test]
    fn efficiency_monotone_across_variants() {
        let v = DeviceSpec::volta_v100();
        let effs: Vec<f64> = [Impl::SotaGpu, Impl::Opt, Impl::OptAt, Impl::OptAtFma, Impl::OptAtFmaReo]
            .iter()
            .map(|i| i.end_to_end_efficiency(&v, 4))
            .collect();
        for w in effs.windows(2) {
            assert!(w[1] > w[0], "each optimization must add: {effs:?}");
        }
    }

    #[test]
    fn weak_scaling_shape_fig17() {
        let m = volta_model();
        // 1024 nodes embarrassing: paper reports 264 TB/s
        let agg = m.weak_scaling(Impl::OptAtFmaReo, 1024, Parallelism::Embarrassing);
        assert!(
            (150e12..400e12).contains(&agg),
            "1024-node aggregate {:.0} TB/s out of band",
            agg / 1e12
        );
        // cooperative is slower but same order (paper: 130 TB/s)
        let coop = m.weak_scaling(
            Impl::OptAtFmaReo,
            1024,
            Parallelism::Cooperative { group_size: 6 },
        );
        assert!(coop < agg);
        assert!(coop > agg * 0.25);
        // node counts to reach 1 TB/s: OPT few, SOTA-GPU more, CPU many
        let need = |im: Impl| -> usize {
            for nodes in [1usize, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048] {
                if m.weak_scaling(im, nodes, Parallelism::Embarrassing) >= 1e12 {
                    return nodes;
                }
            }
            usize::MAX
        };
        let opt_nodes = need(Impl::OptAtFmaReo);
        let sota_nodes = need(Impl::SotaGpu);
        let cpu_nodes = need(Impl::SotaCpu);
        assert!(opt_nodes <= 8, "OPT needs {opt_nodes} nodes (paper: 4)");
        assert!(sota_nodes > opt_nodes && sota_nodes <= 128, "SOTA-GPU {sota_nodes} (paper: 64)");
        assert!(cpu_nodes > sota_nodes, "CPU {cpu_nodes} (paper: 512)");
    }

    #[test]
    fn coop_throughput_ordering_fig14() {
        // 6x1 >= 3x2 >= 2x3 > 1x6 (X-Bus hurts the full-node group)
        let m = ClusterModel::new(DeviceSpec::volta_v100(), 3, 5, 8);
        let total = 16e9 / 6.0;
        let t = |s: usize| {
            let groups = 6 / s;
            m.coop_group_throughput(
                Impl::OptAtFmaReo,
                s,
                total * s as f64,
                Interconnect::nvlink(),
                s > 3,
            ) * groups as f64
        };
        let (t1, t2, t3, t6) = (t(1), t(2), t(3), t(6));
        assert!(t1 >= t2 && t2 >= t3 && t3 > t6, "{t1} {t2} {t3} {t6}");
        assert!(t6 > t1 * 0.3, "1x6 should degrade, not collapse");
    }

    #[test]
    fn total_passes_3d() {
        let m = ClusterModel::new(DeviceSpec::volta_v100(), 3, 9, 8);
        let p = m.total_passes();
        // 7.375 / (1 - 1/8) = 8.43 for infinite levels; 9 levels ~ same
        assert!((p - 8.43).abs() < 0.05, "{p}");
    }
}
