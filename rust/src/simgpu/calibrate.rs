//! Host-side calibration: close the simgpu loop on real execution.
//!
//! The §3.2 machinery elsewhere in this module ranks GPU thread-block
//! configurations against an analytic transaction model and profiles the
//! top three. This module re-targets that loop at the *host*: the
//! candidates are [`ExecConfig`]s (fork width, fork threshold, minimum
//! chunk) for the [`crate::util::par`] layer, the analytic model is a
//! stream-bandwidth-plus-fork-cost estimate, and "profiling" is a short
//! measured run of the real kernel (`upsample` / `masstrans` / `thomas` /
//! quantize). Winners are installed into the par layer's tuned registry
//! ([`crate::util::par::install_tuned`]) keyed by (kernel family, element
//! width, size class), where [`crate::util::par::workers_for_kernel`]
//! consults them. Explicitly set knobs (`--threads`, `--par-threshold`,
//! env) always bypass the table — see `DESIGN.md`.
//!
//! Calibration also measures the machine's achievable memory bandwidth
//! (a forked read+write stream, the host analog of the paper's
//! "achievable single pass throughput" kernel); benches use it as the
//! roofline peak that `BENCH_kernels.json` rows are normalized against
//! (see `docs/performance.md`).

use std::time::Instant;

use crate::refactor::{axis, DimOps};
use crate::simgpu::autotune::prune_and_profile;
use crate::util::par::{self, ExecConfig, KernelClass};
use crate::util::Scalar;

/// Outcome of calibrating one (kernel family, element width, size).
#[derive(Clone, Debug)]
pub struct KernelCalibration {
    pub class: KernelClass,
    /// Element width the measured runs used (4 = f32, 8 = f64).
    pub elem_bytes: usize,
    /// Element count of the measured buffers (decision size for
    /// [`par::workers_for_kernel`]).
    pub elems: usize,
    /// Nominal compulsory memory traffic of one kernel run, bytes.
    pub bytes_moved: u64,
    /// Configuration installed into the tuned registry.
    pub chosen: ExecConfig,
    /// Best measured time of the chosen configuration, seconds.
    pub chosen_time: f64,
    /// Measured time of the untuned default policy.
    pub default_time: f64,
    /// Size of the ranked candidate space.
    pub candidates_ranked: usize,
    /// Configurations actually profiled (top-3 + the default).
    pub profiled: usize,
}

impl KernelCalibration {
    /// Speedup of the calibrated configuration over the untuned default
    /// (≥ 1 by construction: the default is always in the profiled set).
    pub fn speedup(&self) -> f64 {
        self.default_time / self.chosen_time
    }

    /// Achieved throughput of the chosen configuration, GB/s.
    pub fn gbps(&self) -> f64 {
        self.bytes_moved as f64 / self.chosen_time / 1e9
    }

    /// Achieved throughput as a fraction of the measured peak (roofline
    /// position), in percent.
    pub fn pct_peak(&self, peak_gbps: f64) -> f64 {
        if peak_gbps > 0.0 {
            100.0 * self.gbps() / peak_gbps
        } else {
            0.0
        }
    }
}

/// A full calibration run: the measured bandwidth roofline plus the
/// per-kernel winners that were installed.
#[derive(Clone, Debug)]
pub struct CalibrationReport {
    /// Measured achievable read+write stream bandwidth, GB/s.
    pub peak_gbps: f64,
    pub kernels: Vec<KernelCalibration>,
}

/// Measure this machine's achievable memory bandwidth with a forked
/// read+write stream over a cache-busting buffer (32 MiB of f64). This
/// is the empirical roofline every kernel row in `BENCH_kernels.json` is
/// normalized against. Best-of-4 so first-touch page faults in the first
/// pass don't depress the number.
pub fn measure_peak_gbps() -> f64 {
    let elems = 1usize << 22;
    let src = vec![1.0f64; elems];
    let mut dst = vec![0.0f64; elems];
    let workers = par::threads();
    let mut best = f64::INFINITY;
    for _ in 0..4 {
        let t0 = Instant::now();
        par::for_slab_chunks(&src, &mut dst, elems, 1, 1, workers, |_, _, s, d| {
            for (o, v) in d.iter_mut().zip(s) {
                *o = *v + 1.0;
            }
        });
        best = best.min(t0.elapsed().as_secs_f64());
    }
    std::hint::black_box(&dst);
    // 8 bytes read + 8 bytes written per element
    (elems * 16) as f64 / best / 1e9
}

/// The host candidate space: power-of-two fork widths up to
/// `max_threads`, crossed with fork thresholds and minimum chunk sizes.
/// Deterministic (sorted ascending), so model ties resolve stably.
pub fn candidate_configs(max_threads: usize) -> Vec<ExecConfig> {
    let mut widths = vec![1usize];
    let mut t = 2;
    while t < max_threads {
        widths.push(t);
        t *= 2;
    }
    if max_threads > 1 {
        widths.push(max_threads);
    }
    widths.dedup();
    let mut out = Vec::new();
    for &threads in &widths {
        for &par_threshold in &[1usize << 14, 1 << 17, 1 << 20] {
            for &chunk in &[1usize << 10, 1 << 13] {
                out.push(ExecConfig {
                    threads,
                    par_threshold,
                    chunk,
                });
            }
        }
    }
    out
}

/// Analytic host-side time estimate used only to *rank* candidates (the
/// §3.2 role of the transaction model, re-targeted at host cores): a
/// memory-bound stream term that shrinks with the effective fork width,
/// plus a per-task fork/join cost that penalizes oversplitting. Absolute
/// values are irrelevant — only the ordering matters, and the top-3 get
/// measured for real.
pub fn host_model_time(
    class: KernelClass,
    cfg: ExecConfig,
    elems: usize,
    elem_bytes: usize,
) -> f64 {
    // per-core sustained stream bandwidth and fork/join cost, order of
    // magnitude for contemporary server cores; ranking is insensitive to
    // the exact values
    const CORE_BW: f64 = 10e9;
    const FORK_COST: f64 = 20e-6;
    let per_elem = match class {
        KernelClass::Gpk => 3.0,  // read lo+hi rows, write out
        KernelClass::Lpk => 6.0,  // five tap rows + write
        KernelClass::Ipk => 4.0,  // two in-place sweeps, read+write
        KernelClass::Quant => 2.0, // read scalar, write integer
    } * elem_bytes as f64;
    let w = cfg.workers(elems);
    let stream = elems as f64 * per_elem / (CORE_BW * w as f64);
    let fork = if w > 1 { FORK_COST * w as f64 } else { 0.0 };
    stream + fork
}

/// The configuration equivalent to the untuned [`par::workers_for`]
/// policy: all cores, the global threshold, no chunk floor.
pub fn default_host_config() -> ExecConfig {
    ExecConfig {
        threads: par::threads(),
        par_threshold: par::par_threshold(),
        chunk: 1,
    }
}

/// Calibrate one kernel family with an injectable measurement hook:
/// rank the candidate space with [`host_model_time`], profile the top-3
/// **plus the untuned default** with `measure`, and return the measured
/// winner. Because the default is always profiled, the chosen
/// configuration is never slower than the default on the run that chose
/// it. NaN measurements are never selected while any finite time exists
/// ([`f64::total_cmp`] ordering), and selection is deterministic for
/// identical inputs.
pub fn calibrate_kernel_with(
    class: KernelClass,
    elem_bytes: usize,
    elems: usize,
    bytes_moved: u64,
    measure: impl FnMut(ExecConfig) -> f64,
) -> KernelCalibration {
    let mut measure = measure;
    let cands = candidate_configs(par::threads());
    let (top, top_time, kept) = prune_and_profile(
        &cands,
        3,
        |c| host_model_time(class, c, elems, elem_bytes),
        &mut measure,
    );
    let default = default_host_config();
    let default_time = measure(default);
    let (chosen, chosen_time) = if default_time.total_cmp(&top_time).is_lt() {
        (default, default_time)
    } else {
        (top, top_time)
    };
    KernelCalibration {
        class,
        elem_bytes,
        elems,
        bytes_moved,
        chosen,
        chosen_time,
        default_time,
        candidates_ranked: cands.len(),
        profiled: kept.len() + 1,
    }
}

/// Prepared buffers + operator tables for short measured runs of one
/// real kernel family. Shapes are `[m, 64]` with `m = 2^k + 1` chosen so
/// the total element count is near the requested target — the same
/// large-inner layout the production kernels run on.
struct KernelBench<T> {
    class: KernelClass,
    fshape: Vec<usize>,
    cshape: Vec<usize>,
    ops: DimOps<T>,
    src: Vec<T>,
    dst: Vec<T>,
    /// Pristine copy for kernels that mutate in place (IPK).
    pristine: Vec<T>,
    qout: Vec<i64>,
    /// Element count the par layer's fork decision sees.
    decision_elems: usize,
}

impl<T: Scalar> KernelBench<T> {
    fn new(class: KernelClass, target_elems: usize) -> Self {
        const INNER: usize = 64;
        let per = (target_elems / INNER).max(4).next_power_of_two();
        let mf = per + 1; // 2^k + 1 fine nodes along axis 0
        let mc = (mf + 1) / 2;
        let coords: Vec<f64> = (0..mf).map(|i| i as f64 / (mf - 1) as f64).collect();
        let ops = DimOps::new(&coords);
        let fshape = vec![mf, INNER];
        let cshape = vec![mc, INNER];
        let fill = |n: usize| -> Vec<T> {
            (0..n)
                .map(|i| T::from_f64(0.25 + (i % 251) as f64 / 512.0))
                .collect()
        };
        let (src, dst, pristine, qout, decision_elems) = match class {
            KernelClass::Gpk => (
                fill(mc * INNER),
                vec![T::ZERO; mf * INNER],
                Vec::new(),
                Vec::new(),
                mf * INNER,
            ),
            KernelClass::Lpk => (
                fill(mf * INNER),
                vec![T::ZERO; mc * INNER],
                Vec::new(),
                Vec::new(),
                mf * INNER,
            ),
            KernelClass::Ipk => {
                let p = fill(mc * INNER);
                (Vec::new(), p.clone(), p, Vec::new(), mc * INNER)
            }
            KernelClass::Quant => (
                fill(mf * INNER),
                Vec::new(),
                Vec::new(),
                vec![0i64; mf * INNER],
                mf * INNER,
            ),
        };
        KernelBench {
            class,
            fshape,
            cshape,
            ops,
            src,
            dst,
            pristine,
            qout,
            decision_elems,
        }
    }

    /// Nominal compulsory traffic of one run, bytes.
    fn bytes_moved(&self) -> u64 {
        let b = T::BYTES as u64;
        match self.class {
            KernelClass::Gpk | KernelClass::Lpk => (self.src.len() + self.dst.len()) as u64 * b,
            KernelClass::Ipk => 4 * self.dst.len() as u64 * b, // two sweeps, read+write
            KernelClass::Quant => self.src.len() as u64 * (b + 8),
        }
    }

    fn run(&mut self, workers: usize) {
        match self.class {
            KernelClass::Gpk => {
                axis::upsample_with(&self.src, &self.cshape, 0, &self.ops.r, &mut self.dst, workers)
            }
            KernelClass::Lpk => {
                axis::masstrans_with(&self.src, &self.fshape, 0, &self.ops, &mut self.dst, workers)
            }
            KernelClass::Ipk => {
                axis::thomas_with(&mut self.dst, &self.cshape, 0, &self.ops, workers)
            }
            KernelClass::Quant => {
                let inv = 1.0 / 1e-6;
                par::for_slab_chunks(
                    &self.src,
                    &mut self.qout,
                    self.src.len(),
                    1,
                    1,
                    workers,
                    |_, _, s, d| {
                        for (o, v) in d.iter_mut().zip(s) {
                            *o = (v.to_f64() * inv).round() as i64;
                        }
                    },
                );
            }
        }
    }

    /// Best-of-3 measured run under `cfg` (explicit worker counts — the
    /// tuned registry itself is never consulted while calibrating).
    fn measure(&mut self, cfg: ExecConfig) -> f64 {
        let workers = cfg.workers(self.decision_elems);
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            if self.class == KernelClass::Ipk {
                self.dst.copy_from_slice(&self.pristine); // untimed reset
            }
            let t0 = Instant::now();
            self.run(workers);
            best = best.min(t0.elapsed().as_secs_f64());
        }
        std::hint::black_box(&self.dst);
        std::hint::black_box(&self.qout);
        best
    }
}

/// Calibrate every kernel family at each target size for scalar type
/// `T`, install the winners into the par layer's tuned registry, and
/// return the report. Skips nothing: families are always re-measured and
/// re-installed (re-calibration overwrites).
///
/// Note that explicitly set knobs (`--threads`, `--par-threshold`, env
/// vars) bypass the installed table at lookup time, so calibrating under
/// an explicit knob wastes work but is harmless.
pub fn calibrate<T: Scalar>(sizes: &[usize]) -> CalibrationReport {
    let peak_gbps = measure_peak_gbps();
    let mut kernels = Vec::new();
    for &target in sizes {
        for class in KernelClass::ALL {
            let mut kb = KernelBench::<T>::new(class, target);
            let elems = kb.decision_elems;
            let bytes = kb.bytes_moved();
            let cal =
                calibrate_kernel_with(class, T::BYTES, elems, bytes, |cfg| kb.measure(cfg));
            par::install_tuned(class, T::BYTES, par::size_class(elems), cal.chosen);
            kernels.push(cal);
        }
    }
    CalibrationReport { peak_gbps, kernels }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidate_space_deterministic_and_covers_extremes() {
        let a = candidate_configs(8);
        let b = candidate_configs(8);
        assert_eq!(a, b);
        assert!(a.iter().any(|c| c.threads == 1));
        assert!(a.iter().any(|c| c.threads == 8));
        assert!(a.iter().any(|c| c.threads == 4));
        assert!(!a.iter().any(|c| c.threads > 8));
        assert_eq!(candidate_configs(1).iter().map(|c| c.threads).max(), Some(1));
    }

    #[test]
    fn host_model_prefers_parallel_on_large_serial_on_small() {
        let wide = ExecConfig {
            threads: 8,
            par_threshold: 1 << 14,
            chunk: 1 << 10,
        };
        let serial = ExecConfig {
            threads: 1,
            par_threshold: 1 << 14,
            chunk: 1 << 10,
        };
        let big = 1 << 24;
        assert!(
            host_model_time(KernelClass::Lpk, wide, big, 8)
                < host_model_time(KernelClass::Lpk, serial, big, 8)
        );
        // below the threshold the wide config degenerates to serial
        let small = 1 << 10;
        assert_eq!(
            host_model_time(KernelClass::Lpk, wide, small, 8),
            host_model_time(KernelClass::Lpk, serial, small, 8)
        );
    }

    #[test]
    fn injected_measure_is_deterministic_and_nan_safe() {
        // pseudo-measurement: a stable function of the config, NaN for
        // half the candidate space to prove NaN never wins while finite
        // times exist. The default config has chunk == 1 (outside the
        // candidate space), so its measurement is always finite.
        let fake = |cfg: ExecConfig| -> f64 {
            if cfg.chunk == 1 << 13 {
                f64::NAN
            } else {
                1.0 / cfg.threads as f64 + cfg.par_threshold as f64 * 1e-12
            }
        };
        let a = calibrate_kernel_with(KernelClass::Gpk, 8, 1 << 20, 1 << 23, fake);
        let b = calibrate_kernel_with(KernelClass::Gpk, 8, 1 << 20, 1 << 23, fake);
        assert_eq!(a.chosen, b.chosen, "identical inputs, identical choice");
        assert!(a.chosen_time.is_finite(), "NaN measurement must not win");
        assert!(
            a.chosen_time <= a.default_time,
            "default is in the profiled set, so chosen can't be slower"
        );
        assert_eq!(a.profiled, 4);
        assert!(a.candidates_ranked >= 6);
    }

    #[test]
    fn report_math() {
        let cal = KernelCalibration {
            class: KernelClass::Lpk,
            elem_bytes: 8,
            elems: 1 << 20,
            bytes_moved: 2_000_000_000,
            chosen: ExecConfig {
                threads: 4,
                par_threshold: 1 << 14,
                chunk: 1 << 10,
            },
            chosen_time: 1.0,
            default_time: 2.0,
            candidates_ranked: 10,
            profiled: 4,
        };
        assert_eq!(cal.speedup(), 2.0);
        assert!((cal.gbps() - 2.0).abs() < 1e-12);
        assert!((cal.pct_peak(4.0) - 50.0).abs() < 1e-9);
        assert_eq!(cal.pct_peak(0.0), 0.0);
    }
}
