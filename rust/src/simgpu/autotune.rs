//! Heuristic auto-tuning (§3.2): model-rank, prune to top-3, measure, pick.
//!
//! Brute-force profiling of every execution configuration is too expensive
//! to run per input shape; the paper's approach is to rank candidates with
//! the analytic transaction model and only profile the top three. The same
//! logic runs here against the simulated measurement; on real hardware the
//! measurement hook would be a kernel launch.

use crate::simgpu::device::DeviceSpec;
use crate::simgpu::perfmodel::{BlockConfig, Kernel, PerfModel, TABLE2_CONFIGS};

/// Outcome of auto-tuning one kernel.
#[derive(Clone, Debug)]
pub struct AutotuneResult {
    pub kernel: Kernel,
    /// Configuration chosen by model-prune-measure.
    pub chosen: BlockConfig,
    /// Measured time of the chosen configuration, seconds.
    pub chosen_time: f64,
    /// Measured time of the default (one-size-fits-all) configuration.
    pub default_time: f64,
    /// The model's top-3 candidates that were "profiled".
    pub candidates: Vec<BlockConfig>,
    /// How many configurations a brute-force search would have profiled.
    pub search_space: usize,
}

impl AutotuneResult {
    /// Speedup of auto-tuned over the default configuration (the paper
    /// reports 1.2–4.9× across kernels/input sizes).
    pub fn speedup(&self) -> f64 {
        self.default_time / self.chosen_time
    }
}

/// Default configuration used when not tuning (a reasonable middle pick —
/// what "choosing one configuration for all kernels and input sizes"
/// means in §4.2).
pub const DEFAULT_CONFIG: BlockConfig = BlockConfig::new(8, 4, 4);

/// Auto-tune one kernel for a device / size / precision.
pub fn autotune(device: &DeviceSpec, kernel: Kernel, n: usize, elem_bytes: usize) -> AutotuneResult {
    let model = PerfModel::new(device.clone(), n, elem_bytes);

    // rank the full candidate space with the analytic model
    let mut scored: Vec<(BlockConfig, f64)> = TABLE2_CONFIGS
        .iter()
        .map(|&c| (c, model.model_time(kernel, c)))
        .collect();
    scored.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());

    // profile only the top three
    let candidates: Vec<BlockConfig> = scored.iter().take(3).map(|&(c, _)| c).collect();
    let (chosen, chosen_time) = candidates
        .iter()
        .map(|&c| (c, model.measured_time(kernel, c)))
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();

    AutotuneResult {
        kernel,
        chosen,
        chosen_time,
        default_time: model.measured_time(kernel, DEFAULT_CONFIG),
        candidates,
        search_space: TABLE2_CONFIGS.len(),
    }
}

/// Auto-tune all three kernels and return the per-kernel geometric-mean
/// speedup over the default configuration.
pub fn autotune_all(device: &DeviceSpec, n: usize, elem_bytes: usize) -> Vec<AutotuneResult> {
    Kernel::ALL
        .iter()
        .map(|&k| autotune(device, k, n, elem_bytes))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tuned_never_slower_than_default() {
        for device in [DeviceSpec::volta_v100(), DeviceSpec::turing_2080ti()] {
            for n in [65usize, 129, 257, 513] {
                for l in [4usize, 8] {
                    for r in autotune_all(&device, n, l) {
                        assert!(
                            r.speedup() >= 1.0 - 1e-9,
                            "{:?} n={n} L={l}: tuned slower than default",
                            r.kernel
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn speedups_in_paper_band() {
        // §4.2: auto tuning yields 1.2-4.9x over a fixed configuration;
        // allow a wider band but require real improvement somewhere
        let rs = autotune_all(&DeviceSpec::volta_v100(), 513, 4);
        let max = rs.iter().map(|r| r.speedup()).fold(0.0, f64::max);
        assert!(max > 1.1, "expected some kernel to gain >10%, got {max}");
        assert!(max < 10.0);
    }

    #[test]
    fn profiles_only_three() {
        let r = autotune(&DeviceSpec::volta_v100(), Kernel::Gpk, 513, 4);
        assert_eq!(r.candidates.len(), 3);
        assert_eq!(r.search_space, 7);
        assert!(r.candidates.contains(&r.chosen));
    }
}
