//! Heuristic auto-tuning (§3.2): model-rank, prune to top-3, measure, pick.
//!
//! Brute-force profiling of every execution configuration is too expensive
//! to run per input shape; the paper's approach is to rank candidates with
//! the analytic transaction model and only profile the top three. The same
//! logic runs here against the simulated measurement; on real hardware the
//! measurement hook would be a kernel launch.

use crate::simgpu::device::{DeviceSpec, SpecError};
use crate::simgpu::perfmodel::{BlockConfig, Kernel, PerfModel, TABLE2_CONFIGS};

/// The §3.2 prune-and-profile loop, shared by the device auto-tuner and
/// the host calibration pass ([`crate::simgpu::calibrate`]): rank every
/// candidate with a cheap analytic `model`, profile only the `keep` best
/// with the expensive `measure`, and return the measured winner, its
/// time, and the profiled shortlist.
///
/// Ordering uses [`f64::total_cmp`], so a NaN score (e.g. from a
/// nonsensical [`DeviceSpec`]) sorts deterministically *after* every
/// finite time instead of panicking — the old `partial_cmp().unwrap()`
/// here was a crash on any NaN in the model output.
pub fn prune_and_profile<C: Copy>(
    candidates: &[C],
    keep: usize,
    mut model: impl FnMut(C) -> f64,
    mut measure: impl FnMut(C) -> f64,
) -> (C, f64, Vec<C>) {
    assert!(!candidates.is_empty(), "no candidate configurations");
    let mut scored: Vec<(C, f64)> = candidates.iter().map(|&c| (c, model(c))).collect();
    // stable sort: equal scores keep candidate order -> deterministic
    scored.sort_by(|a, b| a.1.total_cmp(&b.1));
    let kept: Vec<C> = scored.iter().take(keep.max(1)).map(|&(c, _)| c).collect();
    let mut best = kept[0];
    let mut best_t = measure(kept[0]);
    for &c in &kept[1..] {
        let t = measure(c);
        if t.total_cmp(&best_t).is_lt() {
            best = c;
            best_t = t;
        }
    }
    (best, best_t, kept)
}

/// Outcome of auto-tuning one kernel.
#[derive(Clone, Debug)]
pub struct AutotuneResult {
    pub kernel: Kernel,
    /// Configuration chosen by model-prune-measure.
    pub chosen: BlockConfig,
    /// Measured time of the chosen configuration, seconds.
    pub chosen_time: f64,
    /// Measured time of the default (one-size-fits-all) configuration.
    pub default_time: f64,
    /// The model's top-3 candidates that were "profiled".
    pub candidates: Vec<BlockConfig>,
    /// How many configurations a brute-force search would have profiled.
    pub search_space: usize,
}

impl AutotuneResult {
    /// Speedup of auto-tuned over the default configuration (the paper
    /// reports 1.2–4.9× across kernels/input sizes).
    pub fn speedup(&self) -> f64 {
        self.default_time / self.chosen_time
    }
}

/// Default configuration used when not tuning (a reasonable middle pick —
/// what "choosing one configuration for all kernels and input sizes"
/// means in §4.2).
pub const DEFAULT_CONFIG: BlockConfig = BlockConfig::new(8, 4, 4);

/// Auto-tune one kernel for a device / size / precision.
pub fn autotune(device: &DeviceSpec, kernel: Kernel, n: usize, elem_bytes: usize) -> AutotuneResult {
    let model = PerfModel::new(device.clone(), n, elem_bytes);
    let (chosen, chosen_time, candidates) = prune_and_profile(
        &TABLE2_CONFIGS,
        3,
        |c| model.model_time(kernel, c),
        |c| model.measured_time(kernel, c),
    );
    AutotuneResult {
        kernel,
        chosen,
        chosen_time,
        default_time: model.measured_time(kernel, DEFAULT_CONFIG),
        candidates,
        search_space: TABLE2_CONFIGS.len(),
    }
}

/// [`autotune`] with up-front spec validation: a device with non-finite
/// or non-positive parameters yields a typed [`SpecError`] instead of
/// NaN-polluted (though no longer panicking) results.
pub fn autotune_checked(
    device: &DeviceSpec,
    kernel: Kernel,
    n: usize,
    elem_bytes: usize,
) -> Result<AutotuneResult, SpecError> {
    device.validate()?;
    Ok(autotune(device, kernel, n, elem_bytes))
}

/// Auto-tune all three kernels and return the per-kernel geometric-mean
/// speedup over the default configuration.
pub fn autotune_all(device: &DeviceSpec, n: usize, elem_bytes: usize) -> Vec<AutotuneResult> {
    Kernel::ALL
        .iter()
        .map(|&k| autotune(device, k, n, elem_bytes))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tuned_never_slower_than_default() {
        for device in [DeviceSpec::volta_v100(), DeviceSpec::turing_2080ti()] {
            for n in [65usize, 129, 257, 513] {
                for l in [4usize, 8] {
                    for r in autotune_all(&device, n, l) {
                        assert!(
                            r.speedup() >= 1.0 - 1e-9,
                            "{:?} n={n} L={l}: tuned slower than default",
                            r.kernel
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn speedups_in_paper_band() {
        // §4.2: auto tuning yields 1.2-4.9x over a fixed configuration;
        // allow a wider band but require real improvement somewhere
        let rs = autotune_all(&DeviceSpec::volta_v100(), 513, 4);
        let max = rs.iter().map(|r| r.speedup()).fold(0.0, f64::max);
        assert!(max > 1.1, "expected some kernel to gain >10%, got {max}");
        assert!(max < 10.0);
    }

    #[test]
    fn nan_device_does_not_panic_and_fails_typed() {
        // regression: the ranking used partial_cmp().unwrap(), so one NaN
        // model time (any non-finite spec field) panicked the tuner
        let mut bad = DeviceSpec::volta_v100();
        bad.mem_bw = f64::NAN;
        let r = autotune(&bad, Kernel::Gpk, 65, 4);
        assert_eq!(r.candidates.len(), 3, "NaN times must still rank");
        assert!(matches!(
            autotune_checked(&bad, Kernel::Gpk, 65, 4),
            Err(SpecError::NonFinite { field: "mem_bw", .. })
        ));
        bad.mem_bw = -1.0;
        assert!(matches!(
            autotune_checked(&bad, Kernel::Gpk, 65, 4),
            Err(SpecError::NonPositive { field: "mem_bw", .. })
        ));
        assert!(autotune_checked(&DeviceSpec::volta_v100(), Kernel::Gpk, 65, 4).is_ok());
    }

    #[test]
    fn prune_and_profile_deterministic_and_nan_safe() {
        let cands = [1usize, 2, 3, 4, 5];
        // model: prefer 3, 1, 5 (NaN model scores sink to the end)
        let model = |c: usize| match c {
            3 => 0.1,
            1 => 0.2,
            5 => 0.3,
            2 => f64::NAN,
            _ => 0.9,
        };
        // measure: NaN for the model's favourite -> must not be chosen
        let measure = |c: usize| if c == 3 { f64::NAN } else { c as f64 };
        let (best, t, kept) = prune_and_profile(&cands, 3, model, measure);
        assert_eq!(kept, vec![3, 1, 5]);
        assert_eq!(best, 1);
        assert_eq!(t, 1.0);
        // identical inputs -> identical outcome
        let again = prune_and_profile(&cands, 3, model, measure);
        assert_eq!((again.0, again.1, again.2), (best, t, kept));
    }

    #[test]
    fn profiles_only_three() {
        let r = autotune(&DeviceSpec::volta_v100(), Kernel::Gpk, 513, 4);
        assert_eq!(r.candidates.len(), 3);
        assert_eq!(r.search_space, 7);
        assert!(r.candidates.contains(&r.chosen));
    }
}
