//! Device and interconnect specifications (published vendor numbers).

/// Why a [`DeviceSpec`] fails validation — typed so callers (the
/// auto-tuner, model-building CLIs) can reject a bad spec up front
/// instead of propagating NaN times through the ranking.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SpecError {
    /// A floating-point field is NaN or infinite.
    NonFinite { field: &'static str, value: f64 },
    /// A floating-point field is zero or negative.
    NonPositive { field: &'static str, value: f64 },
    /// An integer field is zero.
    ZeroField { field: &'static str },
}

impl std::fmt::Display for SpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SpecError::NonFinite { field, value } => {
                write!(f, "device spec field {field} is not finite ({value})")
            }
            SpecError::NonPositive { field, value } => {
                write!(f, "device spec field {field} must be positive (got {value})")
            }
            SpecError::ZeroField { field } => {
                write!(f, "device spec field {field} must be nonzero")
            }
        }
    }
}

impl std::error::Error for SpecError {}

/// One accelerator (or CPU-core) specification.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceSpec {
    pub name: &'static str,
    /// HBM/DRAM bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Bytes per memory transaction (the paper's `S`).
    pub transaction_bytes: usize,
    /// Streaming multiprocessors (occupancy modeling).
    pub sm_count: usize,
    /// Max resident threads per SM.
    pub max_threads_per_sm: usize,
    /// Shared memory per thread block, bytes (VMEM analog: tile budget).
    pub shared_mem_per_block: usize,
    /// FP32 throughput, FLOP/s.
    pub fp32_flops: f64,
    /// FP64 throughput, FLOP/s (1:2 on V100, 1:32 on consumer Turing —
    /// the §3.5 motivation for the FMA optimization).
    pub fp64_flops: f64,
    /// Device memory capacity, bytes.
    pub mem_capacity: usize,
}

impl DeviceSpec {
    /// NVIDIA Volta GV100 as deployed in Summit (16 GB HBM2).
    pub fn volta_v100() -> Self {
        DeviceSpec {
            name: "V100",
            mem_bw: 900e9,
            transaction_bytes: 32,
            sm_count: 80,
            max_threads_per_sm: 2048,
            shared_mem_per_block: 48 * 1024,
            fp32_flops: 15.7e12,
            fp64_flops: 7.8e12,
            mem_capacity: 16 << 30,
        }
    }

    /// NVIDIA RTX 2080 Ti (the paper's "Turing" consumer desktop).
    pub fn turing_2080ti() -> Self {
        DeviceSpec {
            name: "RTX2080Ti",
            mem_bw: 616e9,
            transaction_bytes: 32,
            sm_count: 68,
            max_threads_per_sm: 1024,
            shared_mem_per_block: 48 * 1024,
            fp32_flops: 13.4e12,
            fp64_flops: 0.42e12, // 1:32 ratio — compute-bound risk on f64
            mem_capacity: 11 << 30,
        }
    }

    /// One IBM POWER9 core (Summit has 2×22, 42 usable for compute).
    pub fn power9_core() -> Self {
        DeviceSpec {
            name: "POWER9-core",
            mem_bw: 8e9, // per-core share of the 340 GB/s socket bandwidth
            transaction_bytes: 128,
            sm_count: 1,
            max_threads_per_sm: 4,
            shared_mem_per_block: 512 * 1024,
            fp32_flops: 50e9,
            fp64_flops: 25e9,
            mem_capacity: 512 << 30,
        }
    }

    /// Validate every field the performance models divide by or iterate
    /// over. Returns the first offending field as a typed [`SpecError`].
    pub fn validate(&self) -> Result<(), SpecError> {
        for (field, value) in [
            ("mem_bw", self.mem_bw),
            ("fp32_flops", self.fp32_flops),
            ("fp64_flops", self.fp64_flops),
        ] {
            if !value.is_finite() {
                return Err(SpecError::NonFinite { field, value });
            }
            if value <= 0.0 {
                return Err(SpecError::NonPositive { field, value });
            }
        }
        for (field, value) in [
            ("transaction_bytes", self.transaction_bytes),
            ("sm_count", self.sm_count),
            ("max_threads_per_sm", self.max_threads_per_sm),
            ("shared_mem_per_block", self.shared_mem_per_block),
            ("mem_capacity", self.mem_capacity),
        ] {
            if value == 0 {
                return Err(SpecError::ZeroField { field });
            }
        }
        Ok(())
    }

    /// Peak achievable single-pass (read+write) refactoring throughput:
    /// the paper measures this with a simultaneous read+write benchmark.
    /// Analytically it is `mem_bw / 2` scaled by the ~88% of nominal DRAM
    /// bandwidth such a stream actually sustains (what the paper's
    /// "achievable single pass throughput" kernel measures).
    pub fn single_pass_bw(&self) -> f64 {
        0.88 * self.mem_bw / 2.0
    }
}

/// Point-to-point interconnect between devices.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Interconnect {
    pub name: &'static str,
    /// Uni-directional bandwidth, bytes/s.
    pub bw: f64,
    /// Per-message latency, seconds.
    pub latency: f64,
}

impl Interconnect {
    /// NVLink 2.0 (Summit: 50 GB/s per direction between GPU pairs).
    pub fn nvlink() -> Self {
        Interconnect {
            name: "NVLink2",
            bw: 50e9,
            latency: 5e-6,
        }
    }

    /// POWER9 X-Bus between the two sockets (64 GB/s, shared by 3+3 GPUs).
    pub fn xbus() -> Self {
        Interconnect {
            name: "X-Bus",
            bw: 64e9,
            latency: 8e-6,
        }
    }

    /// Node-to-node EDR InfiniBand (2×12.5 GB/s on Summit).
    pub fn infiniband_edr() -> Self {
        Interconnect {
            name: "EDR-IB",
            bw: 25e9,
            latency: 1.5e-6,
        }
    }

    /// Transfer time for `bytes`.
    pub fn transfer_time(&self, bytes: f64) -> f64 {
        self.latency + bytes / self.bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_sane() {
        let v = DeviceSpec::volta_v100();
        assert!(v.mem_bw > 8e11);
        assert!(v.fp64_flops / v.fp32_flops > 0.4); // 1:2
        let t = DeviceSpec::turing_2080ti();
        assert!(t.fp64_flops / t.fp32_flops < 0.05); // 1:32 — §3.5 story
        assert_eq!(v.single_pass_bw(), 0.88 * 450e9);
    }

    #[test]
    fn validate_catches_bad_fields() {
        for d in [
            DeviceSpec::volta_v100(),
            DeviceSpec::turing_2080ti(),
            DeviceSpec::power9_core(),
        ] {
            assert_eq!(d.validate(), Ok(()), "{}", d.name);
        }
        let mut d = DeviceSpec::volta_v100();
        d.fp64_flops = f64::INFINITY;
        assert!(matches!(
            d.validate(),
            Err(SpecError::NonFinite { field: "fp64_flops", .. })
        ));
        d.fp64_flops = 0.0;
        assert!(matches!(
            d.validate(),
            Err(SpecError::NonPositive { field: "fp64_flops", .. })
        ));
        d = DeviceSpec::volta_v100();
        d.transaction_bytes = 0;
        assert_eq!(
            d.validate(),
            Err(SpecError::ZeroField { field: "transaction_bytes" })
        );
        assert!(d.validate().unwrap_err().to_string().contains("transaction_bytes"));
    }

    #[test]
    fn interconnect_times() {
        let nv = Interconnect::nvlink();
        let t = nv.transfer_time(50e9);
        assert!((t - 1.000005).abs() < 1e-6);
        assert!(Interconnect::xbus().bw > nv.bw); // aggregate, but shared
    }
}
