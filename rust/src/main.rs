//! `mgr` — the data-refactoring coordinator CLI.
//!
//! Every data-path subcommand flows through the unified facade
//! ([`mgr::api::Session`]); the CLI itself performs no dtype dispatch
//! and never touches the per-module compressor/container machinery.
//!
//! Subcommands:
//!
//! * `info` — artifact registry + device model summary.
//! * `refactor` — decompose a Gray-Scott (or random) field into a
//!   progressive representation, report per-class sizes and measured
//!   error annotations; `--out f.mgr` stores the container.
//! * `stream` — run a live Gray-Scott simulation (parameters on flags)
//!   and refactor every snapshot in situ into an append-able `.mgrt`
//!   time-series with temporal delta coding; backpressure bounds the
//!   in-flight snapshot window.
//! * `retrieve` — reconstruct a fidelity prefix from a container:
//!   `--keep K` classes, `--error E` (smallest prefix whose recorded L∞
//!   annotation meets `E`), or `--bytes B` (longest prefix fitting the
//!   byte budget). The selectors are mutually exclusive. The container
//!   is opened **lazily**: only the header and the winning prefix's
//!   segments are read off disk. `--upgrade-from K` demonstrates the
//!   incremental path — retrieve `K` classes first, then upgrade to the
//!   requested fidelity decoding only the delta segments.
//! * `reencode` — rewrite a `.mgr`/`.mgrs` artifact into a truncated
//!   fidelity (pure byte copy), a different entropy codec (entropy
//!   stage only), or a new block grid (decodes only where the tiling
//!   changed) — one artifact, many layouts.
//! * `plan` — place a container's class segments across storage tiers
//!   (reads the header only; no payload is touched).
//! * `place` — *execute* a placement against real tier directories
//!   (`--tiers bb=DIR:pfs=DIR:ar=DIR`): per-class segment bytes are
//!   byte-range-copied out of the artifact onto their tiers, a manifest
//!   is committed next to it, and measured movement telemetry is
//!   printed. `retrieve --from-tiers MANIFEST` then reconstructs the
//!   data straight off the tier ladder, coarse classes first, with an
//!   optional background prefetcher promoting the next class.
//! * `compress` / `roundtrip` — MGARD-style error-bounded compression.
//! * `serve` — long-lived TCP daemon answering `retrieve` /
//!   `retrieve_region` / `retrieve_step` / `upgrade` over the wire
//!   protocol in `docs/serve.md`, sharing one lazily opened container,
//!   shard, or time-series across all connections; `--stats` /
//!   `--shutdown` run the client side against a running daemon.
//! * `pool` — run a batch of jobs through the coordinator worker pool
//!   (formerly `serve`).
//! * `pjrt-check` — execute the AOT artifacts and verify them against the
//!   native core (the cross-layer integration check).

use anyhow::{anyhow, bail, ensure, Context, Result};

use mgr::api::{
    AnyTensor, Dtype, Fidelity, OpenContainer, ReencodeSpec, Series, Session, Sharded,
};
use mgr::compress::Codec;
use mgr::storage::exec::{
    class_sizes, tier_from_key, TierExecutor, TierManifest, TierReadOptions, TierRoot,
    TieredReader, Throttle,
};
use mgr::storage::{place_classes, StepEncoding, StorageTier, TierSpec};
use mgr::coordinator::{Backend, Coordinator, JobMode, JobSpec};
use mgr::grid::Tensor;
use mgr::runtime::EngineHandle;
use mgr::serve::{Client, ServeConfig, ServeTarget, Server};
use mgr::sim::GrayScott;
use mgr::simgpu::{ClusterModel, DeviceSpec};
use mgr::util::cli::Args;
use mgr::util::rng::Rng;
use mgr::util::stats::{linf, time};

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn load_field(args: &Args) -> Result<AnyTensor> {
    let shape = args.get_shape("shape", &[33, 33, 33])?;
    let field: AnyTensor = match args.get_or("input", "grayscott").as_str() {
        "grayscott" => {
            if shape.len() != 3 || shape.iter().any(|&n| n != shape[0]) {
                bail!("grayscott input needs a cubic --shape NxNxN");
            }
            let steps = args.get_usize("steps", 200)?;
            let mut sim = sim_from_args(args, shape[0], args.get_usize("seed", 7)? as u64)?;
            sim.step(steps);
            sim.v_field().into()
        }
        "random" => {
            let mut rng = Rng::new(args.get_usize("seed", 7)? as u64);
            Tensor::<f64>::from_fn(&shape, |_| rng.normal()).into()
        }
        other => bail!("unknown --input '{other}' (grayscott|random)"),
    };
    let dtype: Dtype = args.get_or("dtype", "f64").parse()?;
    Ok(field.cast(dtype))
}

/// Build a Gray-Scott simulation from the CLI reaction/diffusion knobs
/// (`--du --dv --f --k --dt`, defaulting to Pearson's classic values).
/// An unstable `--dt` is rejected up front with the stability limit in
/// the message instead of producing a diverged field.
fn sim_from_args(args: &Args, n: usize, seed: u64) -> Result<GrayScott> {
    Ok(GrayScott::with_params(
        n,
        seed,
        args.get_f64("du", 0.16)?,
        args.get_f64("dv", 0.08)?,
        args.get_f64("f", 0.04)?,
        args.get_f64("k", 0.06)?,
        args.get_f64("dt", 0.95)?,
    )?)
}

/// Build a session matching the CLI knobs for a field of `shape`.
fn session_for(args: &Args, shape: &[usize], dtype: Dtype) -> Result<Session> {
    let codec: Codec = args.get_or("codec", "zlib").parse()?;
    Ok(Session::builder()
        .shape(shape)
        .dtype(dtype)
        .codec(codec)
        .error_bound(args.get_f64("eb", 1e-3)?)
        .build()?)
}

/// Map the mutually exclusive `--keep` / `--error` / `--bytes` flags to a
/// [`Fidelity`]. Combining them is an explicit usage error (they used to
/// be silently prioritized).
fn parse_fidelity(args: &Args) -> Result<Fidelity> {
    let keep = args
        .get("keep")
        .map(|v| {
            v.parse::<usize>()
                .map_err(|_| anyhow!("--keep expects an integer, got '{v}'"))
        })
        .transpose()?;
    let error = args
        .get("error")
        .map(|v| {
            v.parse::<f64>()
                .map_err(|_| anyhow!("--error expects a number, got '{v}'"))
        })
        .transpose()?;
    let bytes = args
        .get("bytes")
        .map(|v| {
            v.parse::<u64>()
                .map_err(|_| anyhow!("--bytes expects a byte count, got '{v}'"))
        })
        .transpose()?;
    Ok(Fidelity::from_flags(keep, error, bytes)?)
}

/// The `--in FILE` (or positional) path of container subcommands.
fn container_path(args: &Args) -> Result<String> {
    args.get("in")
        .map(str::to_string)
        .or_else(|| args.positional.first().cloned())
        .ok_or_else(|| anyhow!("expected --in FILE (or a positional path)"))
}

/// Lazily open the `--in FILE` container: header bytes only — segment
/// payloads stay on disk until a retrieval needs them.
fn open_arg(args: &Args) -> Result<OpenContainer> {
    let path = container_path(args)?;
    OpenContainer::open_file(&path).with_context(|| format!("opening container {path}"))
}

/// Whether `path` starts with the MGRS shard magic (dispatches
/// `retrieve`/`plan`-style subcommands between `.mgr` and `.mgrs`).
/// Short or unreadable files report `false` — the single-container path
/// then produces its descriptive open error.
fn path_is_shard(path: &str) -> bool {
    use std::io::Read;
    let Ok(mut f) = std::fs::File::open(path) else {
        return false;
    };
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic).is_ok() && mgr::storage::shard::is_shard(&magic)
}

/// Whether `path` starts with the MGRT stream magic (dispatches
/// `retrieve` onto the time-series path). Same tolerance as
/// [`path_is_shard`] for short or unreadable files.
fn path_is_stream(path: &str) -> bool {
    use std::io::Read;
    let Ok(mut f) = std::fs::File::open(path) else {
        return false;
    };
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic).is_ok() && mgr::storage::stream::is_stream(&magic)
}

/// Parse the optional `--step T` timestep selector of `retrieve`.
fn parse_step(args: &Args) -> Result<Option<u64>> {
    args.get("step")
        .map(|v| {
            v.parse::<u64>()
                .map_err(|_| anyhow!("--step expects a timestep index, got '{v}'"))
        })
        .transpose()
}

/// Parse the optional `--region i0..i1,j0..j1,…` knob of `retrieve`:
/// one half-open global index range per dimension. Malformed specs name
/// the offending axis and token.
fn parse_region(args: &Args) -> Result<Option<Vec<std::ops::Range<usize>>>> {
    let Some(spec) = args.get("region") else {
        return Ok(None);
    };
    let mut roi = Vec::new();
    for (axis, part) in spec.split(',').enumerate() {
        let (a, b) = part.split_once("..").ok_or_else(|| {
            anyhow!(
                "--region axis {axis}: expected a half-open range like 0..17 \
                 (comma-separated per axis), got '{part}'"
            )
        })?;
        let start: usize = a
            .trim()
            .parse()
            .map_err(|_| anyhow!("--region axis {axis}: bad range start '{a}' in '{part}'"))?;
        let end: usize = b
            .trim()
            .parse()
            .map_err(|_| anyhow!("--region axis {axis}: bad range end '{b}' in '{part}'"))?;
        roi.push(start..end);
    }
    Ok(Some(roi))
}

/// Parse a `--blocks` value: either a single count (slab partitioning,
/// optionally combined with `--axis`) or a comma-separated per-axis
/// list like `4,2,2` (an N-D grid). Malformed specs name the offending
/// axis and token.
fn parse_blocks(spec: &str) -> Result<Vec<usize>> {
    let mut blocks = Vec::new();
    for (axis, tok) in spec.split(',').enumerate() {
        let n: usize = tok.trim().parse().map_err(|_| {
            anyhow!(
                "--blocks axis {axis}: expected a positive block count, got '{}' in '{spec}'",
                tok.trim()
            )
        })?;
        ensure!(
            n >= 1,
            "--blocks axis {axis}: block count must be at least 1, got '{}' in '{spec}'",
            tok.trim()
        );
        blocks.push(n);
    }
    Ok(blocks)
}

/// Parse the optional `--upgrade-from K` staging knob of `retrieve`.
fn parse_upgrade_from(args: &Args) -> Result<Option<usize>> {
    args.get("upgrade-from")
        .map(|v| {
            let k = v
                .parse::<usize>()
                .map_err(|_| anyhow!("--upgrade-from expects an integer, got '{v}'"))?;
            ensure!(k >= 1, "--upgrade-from must be at least 1");
            Ok(k)
        })
        .transpose()
}

fn run(args: &Args) -> Result<()> {
    args.apply_parallelism()?;
    // --autotune on any data subcommand: calibrate fork configurations
    // for both precisions before the real work starts (the `autotune`
    // subcommand prints the full table instead)
    if args.has("autotune") && args.subcommand.as_deref() != Some("autotune") {
        let rep = mgr::simgpu::calibrate::calibrate::<f64>(&[1 << 18]);
        mgr::simgpu::calibrate::calibrate::<f32>(&[1 << 18]);
        println!(
            "autotune: calibrated {} kernel configurations per precision \
             (stream peak {:.1} GB/s)",
            rep.kernels.len(),
            rep.peak_gbps
        );
    }
    match args.subcommand.as_deref() {
        Some("info") => info(args),
        Some("autotune") => autotune_cmd(args),
        Some("refactor") => refactor(args),
        Some("stream") => stream(args),
        Some("retrieve") => retrieve(args),
        Some("reencode") => reencode(args),
        Some("plan") => plan(args),
        Some("place") => place(args),
        Some("compress") | Some("roundtrip") => compress(args),
        Some("serve") => serve(args),
        Some("pool") => pool(args),
        Some("pjrt-check") => pjrt_check(args),
        _ => {
            println!(
                "mgr — multigrid-based hierarchical data refactoring\n\n\
                 usage: mgr <subcommand> [options]\n\n\
                 subcommands:\n\
                 \x20 info                      artifact + device summary\n\
                 \x20 autotune   [--dtype f32|f64] [--elems N]\n\
                 \x20            calibrate per-kernel fork configurations on this machine\n\
                 \x20            (rank candidates analytically, measure the top 3 + default)\n\
                 \x20 refactor   [--shape NxNxN --input grayscott|random --dtype f32|f64]\n\
                 \x20            [--out f.mgr --eb 1e-3 --codec zlib|huff-rle]\n\
                 \x20            [--blocks P [--axis A] | --blocks P0,P1,... --out f.mgrs]\n\
                 \x20            sharded: P slabs on one axis, or an N-D block grid\n\
                 \x20 stream     --out f.mgrt [--n 33 --steps 16 --interval 10 --warmup 200]\n\
                 \x20            [--window 4 --eb 1e-3 --codec zlib|huff-rle --dtype f32|f64]\n\
                 \x20            [--du 0.16 --dv 0.08 --f 0.04 --k 0.06 --dt 0.95]\n\
                 \x20            refactor live Gray-Scott snapshots in situ (temporal deltas)\n\
                 \x20 retrieve   --in f.mgr [--keep K | --error E | --bytes B]\n\
                 \x20            [--upgrade-from K] [--dump raw.bin]\n\
                 \x20 retrieve   --in f.mgrs [--region i0..i1,j0..j1,...]  region-of-interest\n\
                 \x20 retrieve   --in f.mgrt --step T [--region ...]       one timestep\n\
                 \x20 retrieve   --from-tiers f.mgr.tiers.json  walk the executed tier ladder\n\
                 \x20            [--no-prefetch] [--throttle bb=BW,pfs=BW,ar=BW]\n\
                 \x20 reencode   --in f.mgr|f.mgrs --out g.mgr|g.mgrs\n\
                 \x20            [--keep K | --error E | --bytes B]   truncate fidelity (byte copy)\n\
                 \x20            [--codec zlib|huff-rle]              re-run the entropy stage only\n\
                 \x20            [--blocks P0,P1,...] [--workers N]   re-tile onto a new block grid\n\
                 \x20 plan       --in f.mgr\n\
                 \x20 place      --in f.mgr|f.mgrs --tiers bb=DIR:pfs=DIR:ar=DIR\n\
                 \x20            [--cap-bb N --cap-pfs N --cap-ar N]  capacity overrides, bytes\n\
                 \x20            [--throttle bb=BW,...]  emulate tier bandwidth, bytes/s\n\
                 \x20            execute the placement: move the planned bytes for real\n\
                 \x20 compress   [--shape NxNxN --eb 1e-3 --codec zlib|huff-rle --dtype f32|f64]\n\
                 \x20 serve      --in f.mgr|f.mgrs [--addr 127.0.0.1:4860]\n\
                 \x20            [--workers N --max-inflight-mb M]   retrieval daemon\n\
                 \x20 serve      --addr HOST:PORT --stats|--shutdown  client side\n\
                 \x20 pool       [--jobs N --workers N --mode serial|coop|emb]\n\
                 \x20 pjrt-check [--artifacts DIR]\n\n\
                 global options (any subcommand):\n\
                 \x20 --threads N        intra-kernel worker count (0 = all cores)\n\
                 \x20 --par-threshold N  min elements before kernels fork\n\
                 \x20                    (0 = restore default, 1 = always fork)\n\
                 \x20 --autotune         calibrate fork configurations before running\n\
                 \x20                    (explicit --threads/--par-threshold win over\n\
                 \x20                    calibrated values)\n"
            );
            Ok(())
        }
    }
}

/// `mgr autotune`: run the host calibration pass and print the winning
/// fork configuration per kernel family, with roofline positions
/// (achieved GB/s against the measured stream peak).
fn autotune_cmd(args: &Args) -> Result<()> {
    use mgr::simgpu::calibrate;
    let dtype: Dtype = args.get_or("dtype", "f64").parse()?;
    let elems = args.get_usize("elems", 0)?;
    let sizes: Vec<usize> = if elems > 0 {
        vec![elems]
    } else {
        vec![1 << 18, 1 << 21]
    };
    let rep = match dtype {
        Dtype::F32 => calibrate::calibrate::<f32>(&sizes),
        Dtype::F64 => calibrate::calibrate::<f64>(&sizes),
    };
    println!(
        "achievable read+write stream peak: {:.1} GB/s ({} candidate configs ranked per kernel)",
        rep.peak_gbps,
        rep.kernels.first().map_or(0, |k| k.candidates_ranked)
    );
    println!(
        "{:<7} {:>10} {:>9} {:>11} {:>11} {:>8} {:>9} {:>8}",
        "kernel", "elems", "threads", "default ms", "tuned ms", "speedup", "GB/s", "of peak"
    );
    for k in &rep.kernels {
        println!(
            "{:<7} {:>10} {:>9} {:>11.3} {:>11.3} {:>7.2}x {:>9.2} {:>7.1}%",
            k.class.name(),
            k.elems,
            k.chosen.threads,
            k.default_time * 1e3,
            k.chosen_time * 1e3,
            k.speedup(),
            k.gbps(),
            k.pct_peak(rep.peak_gbps)
        );
    }
    println!(
        "installed {} configurations for {dtype} in the process-global tuned registry \
         (explicit --threads/--par-threshold bypass them)",
        rep.kernels.len()
    );
    Ok(())
}

fn info(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    println!("== devices (analytic models, see DESIGN.md) ==");
    for d in [DeviceSpec::volta_v100(), DeviceSpec::turing_2080ti()] {
        let m = ClusterModel::new(d.clone(), 3, 9, 8);
        println!(
            "  {:<10}  mem {:>5.0} GB/s   refactor peak {:>5.1} GB/s",
            d.name,
            d.mem_bw / 1e9,
            m.theoretical_peak() / 1e9
        );
    }
    println!("== artifacts ({dir}) ==");
    match mgr::runtime::Manifest::load(format!("{dir}/manifest.json")) {
        Ok(m) => {
            for v in &m.variants {
                println!(
                    "  {:<40} {:?} {} levels={}",
                    v.name, v.shape, v.dtype, v.nlevels
                );
            }
        }
        Err(e) => println!("  (none: {e})"),
    }
    Ok(())
}

fn refactor(args: &Args) -> Result<()> {
    let data = load_field(args)?;
    let session = session_for(args, data.shape(), data.dtype())?;
    if args.get("blocks").is_some() {
        return refactor_sharded(args, &session, &data);
    }
    let (refactored, secs) = time(|| session.refactor(&data));
    let refactored = refactored?;
    let header = refactored.header();
    println!(
        "refactored {:?} {} ({} levels, {} codec, eb {:.1e}) in {:.1} ms — {:.2} GB/s",
        data.shape(),
        data.dtype(),
        header.nlevels,
        header.codec.name(),
        session.error_bound(),
        secs * 1e3,
        data.nbytes() as f64 / secs / 1e9
    );
    println!(
        "{:<8} {:>12} {:>14} {:>14} {:>14}",
        "class", "values", "seg bytes", "L∞ after", "RMSE after"
    );
    for (k, s) in header.segments.iter().enumerate() {
        println!(
            "{:<8} {:>12} {:>14} {:>14.3e} {:>14.3e}",
            k, s.nvalues, s.bytes, s.linf, s.rmse
        );
    }
    let total = refactored.nbytes();
    println!(
        "total {total} bytes ({:.2}x over raw {})",
        data.nbytes() as f64 / total as f64,
        data.nbytes()
    );

    if let Some(out) = args.get("out") {
        let written = session.store_file(&refactored, out)?;
        println!("stored container {out} ({written} bytes)");
    }
    Ok(())
}

/// `refactor --blocks P [--axis A]` / `--blocks P0,P1,…`: the §3.6
/// sharded create path — partition into slabs or an N-D block grid,
/// refactor every block in parallel, one MGRS artifact out.
fn refactor_sharded(args: &Args, session: &Session, data: &AnyTensor) -> Result<()> {
    let blocks = parse_blocks(args.get("blocks").expect("caller checked --blocks"))?;
    let (sharded, secs, layout) = if blocks.len() == 1 {
        let axis = args.get_usize("axis", 0)?;
        let (s, secs) = time(|| session.refactor_sharded_on(data, blocks[0], axis));
        (s, secs, format!("{} block(s) along axis {axis}", blocks[0]))
    } else {
        ensure!(
            args.get("axis").is_none(),
            "--axis applies to a single --blocks count; a per-axis grid like --blocks {} \
             fixes the layout itself",
            args.get("blocks").unwrap()
        );
        let (s, secs) = time(|| session.refactor_sharded_grid(data, &blocks));
        (s, secs, format!("a {blocks:?} block grid"))
    };
    let sharded = sharded?;
    let header = sharded.header();
    println!(
        "refactored {:?} {} into {layout} \
         ({} codec, eb {:.1e}) in {:.1} ms — {:.2} GB/s aggregate",
        data.shape(),
        data.dtype(),
        session.codec().name(),
        session.error_bound(),
        secs * 1e3,
        data.nbytes() as f64 / secs / 1e9
    );
    println!("{:<8} {:>16} {:>16} {:>12}", "block", "start", "nodes", "bytes");
    for (k, b) in header.blocks.iter().enumerate() {
        println!(
            "{:<8} {:>16} {:>16} {:>12}",
            k,
            format!("{:?}", b.start),
            format!("{:?}", b.len),
            b.bytes
        );
    }
    let total = sharded.total_bytes();
    println!(
        "total {total} bytes ({}-byte index + {} payload; {:.2}x over raw {})",
        sharded.index_bytes(),
        header.payload_bytes(),
        data.nbytes() as f64 / total as f64,
        data.nbytes()
    );
    if let Some(out) = args.get("out") {
        let written = sharded.store_file(out)?;
        println!("stored sharded container {out} ({written} bytes)");
    }
    Ok(())
}

/// `mgr stream`: run a live Gray-Scott simulation and refactor every
/// snapshot in situ into an append-able `.mgrt` time-series, choosing
/// independent vs temporal-delta encoding per step by measured size.
/// The bounded window means the simulation *blocks* instead of
/// buffering when it outruns the encoder.
fn stream(args: &Args) -> Result<()> {
    let out = args
        .get("out")
        .ok_or_else(|| anyhow!("stream needs --out FILE.mgrt"))?;
    let n = args.get_usize("n", 33)?;
    let nsteps = args.get_usize("steps", 16)?;
    let interval = args.get_usize("interval", 10)?;
    let warmup = args.get_usize("warmup", 200)?;
    let window = args.get_usize("window", 4)?;
    ensure!(nsteps >= 1, "--steps must be at least 1");
    ensure!(interval >= 1, "--interval must be at least 1");
    let dtype: Dtype = args.get_or("dtype", "f64").parse()?;
    let mut sim = sim_from_args(args, n, args.get_usize("seed", 7)? as u64)?;
    let session = session_for(args, &[n, n, n], dtype)?;
    sim.step(warmup);

    let writer = session.stream_file(out, window)?;
    let (stats, secs) = time(|| -> Result<_> {
        for _ in 0..nsteps {
            sim.step(interval);
            writer.push(&AnyTensor::from(sim.v_field()).cast(dtype))?;
        }
        Ok(writer.finish()?)
    });
    let stats = stats?;

    println!(
        "streamed {nsteps} step(s) of [{n}, {n}, {n}] {dtype} into {out} in {:.1} ms \
         ({:.1} steps/s, window {window})",
        secs * 1e3,
        nsteps as f64 / secs
    );
    println!(
        "{:<8} {:>12} {:>12} {:>14} {:>12}",
        "step", "encoding", "bytes", "independent", "delta"
    );
    for s in &stats.steps {
        let enc = match s.encoding {
            StepEncoding::Independent => "independent",
            StepEncoding::Delta => "delta",
        };
        println!(
            "{:<8} {:>12} {:>12} {:>14} {:>12}",
            s.index,
            enc,
            s.bytes,
            s.independent_bytes,
            s.delta_bytes.map_or("-".to_string(), |b| b.to_string())
        );
    }
    println!(
        "total {} bytes ({:.3}x of all-independent); peak in-flight {} bytes \
         (bound: (window+1) x {} snapshot bytes = {})",
        stats.total_bytes(),
        stats.delta_ratio(),
        stats.peak_resident_bytes,
        n * n * n * dtype.bytes(),
        (window + 1) * n * n * n * dtype.bytes()
    );
    Ok(())
}

fn retrieve(args: &Args) -> Result<()> {
    if let Some(manifest) = args.get("from-tiers") {
        return retrieve_tiered(args, manifest);
    }
    let path = container_path(args)?;
    if path_is_stream(&path) {
        return retrieve_stream(args, &path);
    }
    ensure!(
        args.get("step").is_none(),
        "--step needs a time-series (.mgrt) artifact; {path} has no timestep axis \
         — `mgr stream` produces one"
    );
    if path_is_shard(&path) {
        return retrieve_sharded(args, &path);
    }
    ensure!(
        args.get("region").is_none(),
        "--region needs a sharded (.mgrs) container; {path} is a single-block MGRC container \
         — refactor with --blocks to shard the domain"
    );
    let container = open_arg(args)?;
    retrieve_container(args, container)
}

/// The single-container retrieval core, shared by `retrieve --in f.mgr`
/// and `retrieve --from-tiers` (the latter feeds a tiered byte source —
/// same container stream, different storage underneath).
fn retrieve_container(args: &Args, container: OpenContainer) -> Result<()> {
    let header = container.header().clone();
    println!(
        "container: shape {:?} {}, {} levels, {} classes, {} codec, eb {:.1e}",
        container.shape(),
        container.dtype(),
        header.nlevels,
        container.nclasses(),
        header.codec.name(),
        header.quant.error_bound
    );
    println!(
        "{:<8} {:>14} {:>14} {:>14}",
        "class", "seg bytes", "L∞ after", "RMSE after"
    );
    for (k, s) in header.segments.iter().enumerate() {
        println!("{:<8} {:>14} {:>14.3e} {:>14.3e}", k, s.bytes, s.linf, s.rmse);
    }

    let fidelity = parse_fidelity(args)?;
    let keep = container.resolve(fidelity)?;
    match fidelity {
        Fidelity::ErrorBound(target) => println!(
            "--error {target:.1e}: smallest satisfying prefix is {keep}/{} classes{}",
            container.nclasses(),
            if header.segments[keep - 1].linf > target {
                " (target unsatisfiable; keeping everything)"
            } else {
                ""
            }
        ),
        Fidelity::ByteBudget(budget) => println!(
            "--bytes {budget}: longest fitting prefix is {keep}/{} classes ({} payload bytes)",
            container.nclasses(),
            header.prefix_bytes(keep)
        ),
        _ => {}
    }

    // retrieval is lazy and self-contained on the container — no session
    // needed, and only the winning prefix's segments leave the disk
    let tensor = if let Some(k0) = parse_upgrade_from(args)? {
        container.resolve(Fidelity::Classes(k0))?;
        ensure!(
            k0 <= keep,
            "--upgrade-from {k0} exceeds the requested fidelity's {keep} classes"
        );
        let (coarse, secs) = time(|| container.retrieve(Fidelity::Classes(k0)));
        let coarse = coarse?;
        let staged = container.bytes_read();
        println!(
            "stage 1: retrieved {k0}/{} classes in {:.1} ms ({staged} container bytes read)",
            container.nclasses(),
            secs * 1e3
        );
        let (upgraded, secs) = time(|| coarse.upgrade(fidelity));
        let upgraded = upgraded?;
        println!(
            "stage 2: upgraded to {keep} classes in {:.1} ms — only {} new bytes read",
            secs * 1e3,
            container.bytes_read() - staged
        );
        upgraded.into_tensor()
    } else {
        let (retrieved, secs) = time(|| container.retrieve(fidelity));
        let retrieved = retrieved?;
        println!("retrieved in {:.1} ms", secs * 1e3);
        retrieved.into_tensor()
    };
    println!(
        "kept {keep}/{} classes — read {} of {} container bytes ({:.1}%) \
         — recorded L∞ {:.3e}, RMSE {:.3e}",
        container.nclasses(),
        container.bytes_read(),
        container.total_bytes(),
        100.0 * container.bytes_read() as f64 / container.total_bytes() as f64,
        header.segments[keep - 1].linf,
        header.segments[keep - 1].rmse
    );

    dump_tensor(args, &tensor)
}

/// `retrieve` on a time-series (`.mgrt`) artifact: print the committed
/// step table, then reconstruct `--step T` (optionally only `--region`)
/// at the requested fidelity. Delta-coded steps resolve their parent
/// chain internally — only the chain's bytes are read, and the result
/// is bit-identical to refactoring that snapshot standalone.
fn retrieve_stream(args: &Args, path: &str) -> Result<()> {
    ensure!(
        args.get("upgrade-from").is_none(),
        "--upgrade-from applies to single containers; series retrieval caches decoded \
         classes per step instead (just retrieve again at the higher fidelity)"
    );
    let series = Series::open_file(path).with_context(|| format!("opening stream {path}"))?;
    println!(
        "stream: shape {:?} {}, {} committed step(s)",
        series.shape(),
        series.dtype(),
        series.nsteps()
    );
    println!(
        "{:<8} {:>12} {:>8} {:>12}",
        "step", "encoding", "parent", "bytes"
    );
    for s in series.steps() {
        println!(
            "{:<8} {:>12} {:>8} {:>12}",
            s.index,
            if s.delta { "delta" } else { "independent" },
            s.parent.map_or("-".to_string(), |p| p.to_string()),
            s.bytes
        );
    }

    let Some(t) = parse_step(args)? else {
        println!("(pass --step T to reconstruct a timestep)");
        return Ok(());
    };
    let fidelity = parse_fidelity(args)?;
    let tensor = if let Some(roi) = parse_region(args)? {
        let (x, secs) = time(|| series.retrieve_region_step(t, &roi, fidelity));
        let x = x?;
        println!(
            "retrieved region {:?} of step {t} in {:.1} ms",
            x.shape(),
            secs * 1e3
        );
        x
    } else {
        let (x, secs) = time(|| series.retrieve_step(t, fidelity));
        let x = x?;
        println!("retrieved step {t} in {:.1} ms", secs * 1e3);
        x
    };
    let info = series.step(t)?;
    println!(
        "step {t} is {}; read {} stream bytes for it{}",
        if info.delta {
            format!("delta-coded (parent {})", info.parent.unwrap_or_default())
        } else {
            "independent".to_string()
        },
        series.bytes_read(),
        if info.delta {
            " (its parent chain included)"
        } else {
            ""
        }
    );
    dump_tensor(args, &tensor)
}

/// `retrieve` on a sharded (`.mgrs`) artifact: whole-domain reassembly,
/// or `--region` for region-of-interest retrieval that opens only the
/// intersecting blocks (the bytes-read report shows the saving).
fn retrieve_sharded(args: &Args, path: &str) -> Result<()> {
    ensure!(
        args.get("upgrade-from").is_none(),
        "--upgrade-from applies to single containers; sharded retrieval caches per-block \
         decodes instead (just retrieve again at the higher fidelity)"
    );
    let sharded = Sharded::open_file(path).with_context(|| format!("opening shard {path}"))?;
    let header = sharded.header();
    println!(
        "shard: shape {:?} {}, {} block(s) in a {:?} grid, {}-byte index",
        sharded.shape(),
        sharded.dtype(),
        sharded.nblocks(),
        sharded.grid(),
        sharded.index_bytes()
    );
    println!("{:<8} {:>16} {:>16} {:>12}", "block", "start", "nodes", "bytes");
    for (k, b) in header.blocks.iter().enumerate() {
        println!(
            "{:<8} {:>16} {:>16} {:>12}",
            k,
            format!("{:?}", b.start),
            format!("{:?}", b.len),
            b.bytes
        );
    }

    let fidelity = parse_fidelity(args)?;
    let tensor = if let Some(roi) = parse_region(args)? {
        let hit = sharded.blocks_for_region(&roi)?;
        println!(
            "region {:?} intersects block(s) {hit:?} — the other {} block(s) stay untouched",
            roi,
            sharded.nblocks() - hit.len()
        );
        let (t, secs) = time(|| sharded.retrieve_region(&roi, fidelity));
        let t = t?;
        println!("retrieved region {:?} in {:.1} ms", t.shape(), secs * 1e3);
        t
    } else {
        let (t, secs) = time(|| sharded.retrieve(fidelity));
        let t = t?;
        println!("retrieved full domain in {:.1} ms", secs * 1e3);
        t
    };
    println!(
        "read {} of {} shard bytes ({:.1}%)",
        sharded.bytes_read(),
        sharded.total_bytes(),
        100.0 * sharded.bytes_read() as f64 / sharded.total_bytes() as f64
    );
    dump_tensor(args, &tensor)
}

/// `mgr reencode`: rewrite an artifact into a new fidelity, codec, or
/// block layout without a full decode → re-refactor round trip (see
/// [`mgr::api::reencode`]). The report shows how much work was
/// actually done — a pure truncation decodes nothing.
fn reencode(args: &Args) -> Result<()> {
    let path = container_path(args)?;
    let out = args
        .get("out")
        .ok_or_else(|| anyhow!("reencode needs --out FILE"))?;
    let codec = args.get("codec").map(|c| c.parse::<Codec>()).transpose()?;
    let blocks = args.get("blocks").map(parse_blocks).transpose()?;
    let spec = ReencodeSpec {
        fidelity: parse_fidelity(args)?,
        codec,
        blocks_per_axis: blocks,
    };
    let workers = args.get_usize("workers", 4)?;
    let (report, secs) =
        time(|| mgr::api::reencode::reencode_file(&path, out, &spec, workers));
    let report = report?;
    println!(
        "reencoded {path} -> {out} in {:.1} ms: {} -> {} bytes, {} -> {} block(s)",
        secs * 1e3,
        report.bytes_in,
        report.bytes_out,
        report.blocks_in,
        report.blocks_out
    );
    println!(
        "  {} block(s) copied byte-for-byte; {} of {} payload bytes entropy-decoded ({:.1}%)",
        report.blocks_copied,
        report.bytes_decoded,
        report.bytes_in,
        100.0 * report.bytes_decoded as f64 / report.bytes_in as f64
    );
    Ok(())
}

/// Honor `--dump raw.bin`: always dumps f64 LE (f32 data is widened).
fn dump_tensor(args: &Args, tensor: &AnyTensor) -> Result<()> {
    if let Some(dump) = args.get("dump") {
        let mut raw = Vec::with_capacity(tensor.len() * 8);
        for v in tensor.data_f64() {
            raw.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(dump, raw)?;
        println!("dumped {} little-endian f64 values to {dump}", tensor.len());
    }
    Ok(())
}

fn plan(args: &Args) -> Result<()> {
    let container = open_arg(args)?;
    let session = Session::builder().for_header(container.header()).build()?;
    let placement = session.plan_header(container.header())?;
    println!(
        "placement of {} class segments ({} payload bytes) across {} tiers \
         (planned from the {}-byte header alone):",
        container.nclasses(),
        container.header().payload_bytes(),
        session.tiers().len(),
        container.bytes_read()
    );
    for (k, tier) in placement.assignment.iter().enumerate() {
        println!(
            "  class {k}: {:>12} B -> {tier:?}{}",
            placement.bytes[k],
            if placement.is_over_capacity(k) {
                "  (OVER CAPACITY)"
            } else {
                ""
            }
        );
    }
    for keep in 1..=container.nclasses() {
        println!(
            "  retrieve {keep} classes: {:.3} s",
            placement.retrieval_time(session.tiers(), keep)?
        );
    }
    Ok(())
}

/// Parse `--throttle bb=BW,pfs=BW,ar=BW` (bytes/s, symmetric
/// read/write, zero added latency) into per-tier throttles.
fn parse_throttles(args: &Args) -> Result<Vec<(StorageTier, Throttle)>> {
    let Some(spec) = args.get("throttle") else {
        return Ok(Vec::new());
    };
    let mut out = Vec::new();
    for part in spec.split(',').filter(|p| !p.is_empty()) {
        let (key, bw) = part
            .split_once('=')
            .ok_or_else(|| anyhow!("throttle '{part}' is not key=BYTES_PER_SEC"))?;
        let tier = tier_from_key(key)
            .ok_or_else(|| anyhow!("unknown tier key '{key}' in --throttle (bb, pfs, ar)"))?;
        let bw: f64 = bw
            .parse()
            .map_err(|_| anyhow!("throttle bandwidth '{bw}' is not a number"))?;
        ensure!(bw > 0.0, "throttle bandwidth must be positive, got {bw}");
        out.push((tier, Throttle::bandwidth(bw)));
    }
    Ok(out)
}

/// Parse `--tiers bb=DIR:pfs=DIR:ar=DIR` (fastest tier first) into
/// executor roots plus matching placement specs: Summit-preset
/// bandwidth/latency figures, capacities overridable in bytes via
/// `--cap-bb/--cap-pfs/--cap-ar`, and any `--throttle` entries attached
/// to their roots.
fn parse_tier_roots(args: &Args) -> Result<(Vec<TierRoot>, Vec<TierSpec>)> {
    let spec = args.get("tiers").ok_or_else(|| {
        anyhow!("--tiers bb=DIR:pfs=DIR:ar=DIR is required (fastest tier first)")
    })?;
    let throttles = parse_throttles(args)?;
    let mut roots: Vec<TierRoot> = Vec::new();
    let mut specs = Vec::new();
    for part in spec.split(':').filter(|p| !p.is_empty()) {
        let (key, dir) = part
            .split_once('=')
            .ok_or_else(|| anyhow!("tier spec '{part}' is not key=DIR (keys: bb, pfs, ar)"))?;
        let tier = tier_from_key(key)
            .ok_or_else(|| anyhow!("unknown tier key '{key}' in --tiers (bb, pfs, ar)"))?;
        ensure!(!dir.is_empty(), "tier '{key}' has an empty directory");
        ensure!(
            !roots.iter().any(|r| r.tier == tier),
            "tier '{key}' listed twice in --tiers"
        );
        let mut tier_spec = match tier {
            StorageTier::BurstBuffer => TierSpec::burst_buffer(),
            StorageTier::ParallelFs => TierSpec::parallel_fs(),
            StorageTier::Archive => TierSpec::archive(),
        };
        if let Some(cap) = args.get(&format!("cap-{key}")) {
            tier_spec.capacity = cap
                .parse()
                .map_err(|_| anyhow!("--cap-{key} expects bytes, got '{cap}'"))?;
        }
        let mut root = TierRoot::new(tier, dir);
        if let Some(&(_, th)) = throttles.iter().find(|(t, _)| *t == tier) {
            root = root.throttled(th);
        }
        roots.push(root);
        specs.push(tier_spec);
    }
    ensure!(!roots.is_empty(), "--tiers names no tiers");
    Ok((roots, specs))
}

/// `place`: plan a placement for the artifact's real segment sizes,
/// then *execute* it — byte-range-copy every class segment onto its
/// tier directory, commit the manifest, and print measured (not
/// modeled) movement telemetry.
fn place(args: &Args) -> Result<()> {
    let path = container_path(args)?;
    let (roots, specs) = parse_tier_roots(args)?;
    let sizes = class_sizes(&path)?;
    let placement = place_classes(&sizes, &specs);
    println!(
        "placing {} class segments ({} payload bytes) across {} real tier roots:",
        sizes.len(),
        sizes.iter().sum::<u64>(),
        roots.len()
    );
    for (k, tier) in placement.assignment.iter().enumerate() {
        println!(
            "  class {k}: {:>12} B -> {tier:?}{}",
            placement.bytes[k],
            if placement.is_over_capacity(k) {
                "  (OVER CAPACITY)"
            } else {
                ""
            }
        );
    }
    let executor = TierExecutor::new(roots)?;
    let (manifest, secs) = time(|| executor.execute(&placement, &path));
    let manifest = manifest?;
    println!(
        "moved {} class bytes (+ {} meta bytes) in {:.1} ms; manifest committed to {}",
        placement.bytes.iter().sum::<u64>(),
        manifest.meta_bytes,
        secs * 1e3,
        TierManifest::path_for(&path).display()
    );
    println!("tier telemetry (measured):\n{}", executor.stats().to_json());
    Ok(())
}

/// `retrieve --from-tiers MANIFEST`: reconstruct the container straight
/// off the tier ladder an executed placement left behind — coarse
/// classes stream from their tier files first (optionally throttled,
/// optionally prefetched ahead of upgrades) — then print the measured
/// movement telemetry. The retrieval core (and its result) is identical
/// to `retrieve --in` on the original artifact.
fn retrieve_tiered(args: &Args, manifest_path: &str) -> Result<()> {
    ensure!(
        args.get("region").is_none() && args.get("step").is_none(),
        "--from-tiers serves single-container manifests (no --region/--step)"
    );
    let options = TierReadOptions {
        prefetch: !args.has("no-prefetch"),
        throttles: parse_throttles(args)?,
    };
    let reader = TieredReader::open_with(manifest_path, options)?;
    let m = reader.manifest();
    ensure!(
        !m.artifact.to_string_lossy().ends_with(".mgrs"),
        "--from-tiers retrieval serves single-container (.mgr) manifests; shard placements \
         execute fine, but retrieve shards through the original artifact"
    );
    println!(
        "tiered manifest: {} — {} bytes in {} class segments (+{} meta bytes)",
        m.artifact.display(),
        m.total_bytes,
        m.nclasses,
        m.meta_bytes
    );
    for c in &m.classes {
        println!("  class {}: {:>12} B on {:?}", c.class, c.bytes, c.tier);
    }
    let container = OpenContainer::open(reader.source())?;
    retrieve_container(args, container)?;
    let stats = reader.stats();
    println!(
        "prefetcher: {} classes promoted ahead of use, {} reads served from memory",
        stats.prefetched_classes, stats.prefetch_hits
    );
    println!("tier telemetry (measured):\n{}", stats.to_json());
    Ok(())
}

fn compress(args: &Args) -> Result<()> {
    let data = load_field(args)?;
    let session = session_for(args, data.shape(), data.dtype())?;
    let eb = session.error_bound();
    let blob = session.compress(&data)?;
    let stats = session.stats();
    println!(
        "compressed {:?} {}: {} -> {} bytes (ratio {:.2}x) in {:.1} ms",
        data.shape(),
        data.dtype(),
        blob.original_bytes,
        blob.payload.len(),
        blob.ratio(),
        stats.compress_total() * 1e3
    );
    println!(
        "  breakdown: decompose {:.1} ms, quantize {:.1} ms, {} {:.1} ms",
        stats.decompose_s * 1e3,
        stats.quantize_s * 1e3,
        session.codec().name(),
        stats.encode_s * 1e3
    );
    let back = session.decompress(&blob)?;
    let err = linf(&back.data_f64(), &data.data_f64());
    println!(
        "  decompressed in {:.1} ms; L∞ error {:.3e} (bound {eb:.1e}) — {}",
        session.stats().decompress_total() * 1e3,
        err,
        if err <= eb { "OK" } else { "VIOLATED" }
    );
    if err > eb {
        bail!("error bound violated");
    }
    Ok(())
}

/// `mgr serve`: share one lazily opened container/shard/time-series
/// behind a TCP front (daemon mode), or talk to a running daemon
/// (`--stats`, `--shutdown`).
fn serve(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:4860");
    if args.has("stats") {
        let mut client = Client::connect(&addr).with_context(|| format!("connecting to {addr}"))?;
        println!("{}", client.stats().map_err(|e| anyhow!("{e}"))?);
        return Ok(());
    }
    if args.has("shutdown") {
        let mut client = Client::connect(&addr).with_context(|| format!("connecting to {addr}"))?;
        client.shutdown_server().map_err(|e| anyhow!("{e}"))?;
        println!("daemon at {addr} acknowledged shutdown");
        return Ok(());
    }

    let path = container_path(args)?;
    let target = ServeTarget::open_file(&path).with_context(|| format!("opening {path}"))?;
    let kind = match &target {
        ServeTarget::Container(_) => "container",
        ServeTarget::Shard(_) => "shard",
        ServeTarget::Series(_) => "time-series",
    };
    let config = ServeConfig {
        workers: args.get_usize("workers", ServeConfig::default().workers)?,
        max_inflight_bytes: args.get_usize("max-inflight-mb", 256)? as u64 * 1024 * 1024,
    };
    let server = Server::start(target, addr.as_str(), config.clone())
        .with_context(|| format!("binding {addr}"))?;
    println!(
        "serving {kind} {path} on {} ({} workers, {} MiB in-flight budget) — \
         stop with `mgr serve --addr {} --shutdown`",
        server.addr(),
        config.workers,
        config.max_inflight_bytes / (1024 * 1024),
        server.addr()
    );
    let stats = server.wait();
    println!("daemon stopped; final telemetry: {}", stats.to_json());
    Ok(())
}

/// `mgr pool`: run a batch of refactor jobs through the coordinator
/// worker pool (this subcommand was called `serve` before the TCP
/// daemon took that name).
fn pool(args: &Args) -> Result<()> {
    let njobs = args.get_usize("jobs", 8)?;
    let workers = args.get_usize("workers", 4)?;
    let shape = args.get_shape("shape", &[33, 33, 33])?;
    let mode = match args.get_or("mode", "serial").as_str() {
        "serial" => JobMode::Serial,
        "coop" => JobMode::Cooperative { workers: 3 },
        "emb" => JobMode::Embarrassing { devices: 2 },
        other => bail!("unknown mode '{other}'"),
    };
    let mut rng = Rng::new(11);
    let jobs: Vec<JobSpec> = (0..njobs)
        .map(|i| JobSpec {
            name: format!("job{i}"),
            data: Tensor::from_fn(&shape, |_| rng.normal()),
            mode,
            error_bound: None,
            codec: Codec::Zlib,
        })
        .collect();
    let total_bytes: usize = jobs.iter().map(|j| j.data.nbytes()).sum();
    let coord = Coordinator::new(Backend::Native, workers);
    let (results, secs) = time(|| coord.run_batch(jobs));
    let ok = results.iter().filter(|r| r.is_ok()).count();
    println!(
        "served {ok}/{njobs} jobs ({} workers) in {:.1} ms — {:.2} GB/s aggregate",
        workers,
        secs * 1e3,
        total_bytes as f64 / secs / 1e9
    );
    for r in results {
        let r = r?;
        println!(
            "  {:<8} {:.1} ms  {:.2} GB/s",
            r.name,
            r.seconds * 1e3,
            r.throughput_gbps()
        );
    }
    Ok(())
}

fn pjrt_check(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let engine = EngineHandle::spawn(dir.into())?;
    let variants = engine.variants()?;
    println!("checking {} artifacts against the native core", variants.len());
    let mut checked = 0;
    for v in variants.iter().filter(|v| v.op == "decompose") {
        use mgr::grid::Hierarchy;
        use mgr::refactor::Refactorer;
        let shape = v.shape.clone();
        let h = Hierarchy::uniform(&shape);
        let mut rng = Rng::new(42);
        let err = if v.dtype == "float32" {
            let t = Tensor::from_fn(&shape, |_| rng.normal() as f32);
            let got = engine.run(&v.name, &t, &h.coords().to_vec())?;
            let mut want = t.clone();
            Refactorer::new(h.clone()).decompose(&mut want);
            linf(got.data(), want.data())
        } else {
            let t = Tensor::from_fn(&shape, |_| rng.normal());
            let got = engine.run(&v.name, &t, &h.coords().to_vec())?;
            let mut want = t.clone();
            Refactorer::new(h.clone()).decompose(&mut want);
            linf(got.data(), want.data())
        };
        let tol = if v.dtype == "float32" { 1e-3 } else { 1e-9 };
        println!("  {:<40} L∞(pjrt, native) = {err:.2e}", v.name);
        if err > tol {
            bail!("{}: PJRT and native disagree ({err})", v.name);
        }
        checked += 1;
    }
    println!("pjrt-check OK ({checked} decompose artifacts verified)");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn tiers_spec_parses_in_order_with_overrides() {
        let a = args(
            "place --in f.mgr --tiers bb=/t/bb:pfs=/t/pfs:ar=/t/ar --cap-bb 4096 \
             --throttle bb=1e6",
        );
        let (roots, specs) = parse_tier_roots(&a).unwrap();
        assert_eq!(roots.len(), 3);
        assert_eq!(roots[0].tier, StorageTier::BurstBuffer);
        assert_eq!(roots[0].root, std::path::PathBuf::from("/t/bb"));
        assert!(roots[0].throttle.is_some(), "--throttle bb= attaches to the bb root");
        assert!(roots[1].throttle.is_none() && roots[2].throttle.is_none());
        assert_eq!(specs[0].capacity, 4096, "--cap-bb overrides the preset");
        assert_eq!(specs[1].capacity, TierSpec::parallel_fs().capacity);
        assert_eq!(roots[2].tier, StorageTier::Archive);
    }

    #[test]
    fn tiers_spec_errors_name_the_problem() {
        let missing = parse_tier_roots(&args("place --in f.mgr")).unwrap_err();
        assert!(missing.to_string().contains("--tiers"), "{missing}");
        let bad_key = parse_tier_roots(&args("place --tiers nvme=/t")).unwrap_err();
        assert!(bad_key.to_string().contains("nvme"), "{bad_key}");
        let no_eq = parse_tier_roots(&args("place --tiers bb")).unwrap_err();
        assert!(no_eq.to_string().contains("key=DIR"), "{no_eq}");
        let dup = parse_tier_roots(&args("place --tiers bb=/a:bb=/b")).unwrap_err();
        assert!(dup.to_string().contains("twice"), "{dup}");
    }

    #[test]
    fn throttle_spec_parses_and_validates() {
        let ths = parse_throttles(&args("retrieve --throttle bb=2.5e9,ar=1e6")).unwrap();
        assert_eq!(ths.len(), 2);
        assert_eq!(ths[0].0, StorageTier::BurstBuffer);
        assert_eq!(ths[0].1.read_bw, 2.5e9);
        assert_eq!(ths[1].0, StorageTier::Archive);
        assert!(parse_throttles(&args("retrieve")).unwrap().is_empty());
        assert!(parse_throttles(&args("retrieve --throttle bb=-5")).is_err());
        assert!(parse_throttles(&args("retrieve --throttle bb")).is_err());
    }

    #[test]
    fn keep_and_error_together_is_a_usage_error() {
        // regression: `retrieve --keep K --error E` used to silently
        // prefer --error and ignore --keep
        let a = args("retrieve --in f.mgr --keep 2 --error 1e-3");
        let err = parse_fidelity(&a).unwrap_err().to_string();
        assert!(err.contains("--keep") && err.contains("--error"), "{err}");
        assert!(err.contains("mutually exclusive"), "{err}");
        // all other pairings are rejected too
        assert!(parse_fidelity(&args("retrieve --keep 2 --bytes 100")).is_err());
        assert!(parse_fidelity(&args("retrieve --error 1e-3 --bytes 100")).is_err());
    }

    #[test]
    fn single_selectors_parse() {
        let keep = parse_fidelity(&args("retrieve --keep 3")).unwrap();
        assert_eq!(keep, Fidelity::Classes(3));
        let error = parse_fidelity(&args("retrieve --error 1e-2")).unwrap();
        assert_eq!(error, Fidelity::ErrorBound(1e-2));
        let bytes = parse_fidelity(&args("retrieve --bytes 4096")).unwrap();
        assert_eq!(bytes, Fidelity::ByteBudget(4096));
        assert_eq!(parse_fidelity(&args("retrieve")).unwrap(), Fidelity::All);
    }

    #[test]
    fn upgrade_from_parses_and_validates() {
        assert_eq!(parse_upgrade_from(&args("retrieve")).unwrap(), None);
        let staged = parse_upgrade_from(&args("retrieve --upgrade-from 2")).unwrap();
        assert_eq!(staged, Some(2));
        assert!(parse_upgrade_from(&args("retrieve --upgrade-from 0")).is_err());
        assert!(parse_upgrade_from(&args("retrieve --upgrade-from x")).is_err());
    }

    #[test]
    fn region_specs_parse() {
        assert_eq!(parse_region(&args("retrieve")).unwrap(), None);
        let roi = parse_region(&args("retrieve --region 0..17,4..9")).unwrap().unwrap();
        assert_eq!(roi, vec![0..17, 4..9]);
        let roi = parse_region(&args("retrieve --region 10..15")).unwrap().unwrap();
        assert_eq!(roi, vec![10..15]);
        assert!(parse_region(&args("retrieve --region 0-17")).is_err());
        assert!(parse_region(&args("retrieve --region x..9")).is_err());
        assert!(parse_region(&args("retrieve --region 0..y")).is_err());
    }

    #[test]
    fn region_errors_name_the_axis_and_token() {
        // a malformed component must point at its axis, not just fail
        let err = parse_region(&args("retrieve --region 0..9,4-7"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("axis 1") && err.contains("'4-7'"), "{err}");
        let err = parse_region(&args("retrieve --region x..9"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("axis 0") && err.contains("'x'"), "{err}");
        let err = parse_region(&args("retrieve --region 0..9,1..y,2..3"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("axis 1") && err.contains("'y'"), "{err}");
        let err = parse_region(&args("retrieve --region 0..9,,3..4"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("axis 1"), "{err}");
    }

    #[test]
    fn blocks_specs_parse() {
        assert_eq!(parse_blocks("4").unwrap(), vec![4]);
        assert_eq!(parse_blocks("4,2,2").unwrap(), vec![4, 2, 2]);
        assert_eq!(parse_blocks(" 2 , 1 ").unwrap(), vec![2, 1]);
    }

    #[test]
    fn blocks_errors_name_the_axis_and_token() {
        let err = parse_blocks("4,x,2").unwrap_err().to_string();
        assert!(err.contains("axis 1") && err.contains("'x'"), "{err}");
        let err = parse_blocks("-3").unwrap_err().to_string();
        assert!(err.contains("axis 0") && err.contains("'-3'"), "{err}");
        let err = parse_blocks("4,0").unwrap_err().to_string();
        assert!(err.contains("axis 1") && err.contains("at least 1"), "{err}");
        let err = parse_blocks("").unwrap_err().to_string();
        assert!(err.contains("axis 0"), "{err}");
        let err = parse_blocks("2,,2").unwrap_err().to_string();
        assert!(err.contains("axis 1"), "{err}");
        let err = parse_blocks("2,3.5").unwrap_err().to_string();
        assert!(err.contains("axis 1") && err.contains("'3.5'"), "{err}");
    }

    #[test]
    fn step_selector_parses() {
        assert_eq!(parse_step(&args("retrieve")).unwrap(), None);
        assert_eq!(parse_step(&args("retrieve --step 3")).unwrap(), Some(3));
        assert!(parse_step(&args("retrieve --step x")).is_err());
        assert!(parse_step(&args("retrieve --step -1")).is_err());
    }

    #[test]
    fn unstable_dt_is_rejected_with_the_limit() {
        // 6·0.16·1.2 > 1: the CLI must refuse before simulating
        let err = sim_from_args(&args("stream --dt 1.2"), 9, 1)
            .unwrap_err()
            .to_string();
        assert!(err.contains("stability"), "{err}");
        // defaults and explicit stable overrides both construct
        assert!(sim_from_args(&args("stream"), 9, 1).is_ok());
        assert!(sim_from_args(&args("stream --f 0.03 --k 0.061 --dt 0.5"), 9, 1).is_ok());
        assert!(sim_from_args(&args("stream --du x"), 9, 1).is_err());
    }

    #[test]
    fn stream_then_retrieve_step_roundtrip() {
        let path = std::env::temp_dir().join(format!("mgr_cli_stream_{}.mgrt", std::process::id()));
        let p = path.to_str().unwrap();
        stream(&args(&format!(
            "stream --out {p} --n 9 --steps 3 --interval 2 --warmup 20 --window 2"
        )))
        .unwrap();
        assert!(path_is_stream(p) && !path_is_shard(p));
        // full retrieval of a committed step, then the info-only form
        retrieve(&args(&format!("retrieve --in {p} --step 2 --keep 2"))).unwrap();
        retrieve(&args(&format!("retrieve --in {p} --region 0..4,0..9,2..5 --step 1"))).unwrap();
        retrieve(&args(&format!("retrieve --in {p}"))).unwrap();
        // out-of-range step surfaces the typed error
        assert!(retrieve(&args(&format!("retrieve --in {p} --step 9"))).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn malformed_selector_values_error() {
        assert!(parse_fidelity(&args("retrieve --keep x")).is_err());
        assert!(parse_fidelity(&args("retrieve --error x")).is_err());
        assert!(parse_fidelity(&args("retrieve --bytes -4")).is_err());
        assert!(parse_fidelity(&args("retrieve --keep 0")).is_err());
        assert!(parse_fidelity(&args("retrieve --error -1")).is_err());
    }
}
