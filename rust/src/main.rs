//! `mgr` — the data-refactoring coordinator CLI.
//!
//! Subcommands:
//!
//! * `info` — artifact registry + device model summary.
//! * `refactor` — decompose a Gray-Scott (or random) field, report class
//!   sizes and error-control norms; `--out f.mgr` additionally writes a
//!   progressive container with per-class segments.
//! * `retrieve` — reconstruct a fidelity prefix from a container
//!   (`--keep K` classes, or `--error E` for the smallest prefix whose
//!   recorded L∞ annotation meets `E`).
//! * `compress` / `roundtrip` — MGARD-style error-bounded compression.
//! * `serve` — run a batch of jobs through the coordinator worker pool.
//! * `pjrt-check` — execute the AOT artifacts and verify them against the
//!   native core (the cross-layer integration check).

use anyhow::{anyhow, bail, ensure, Context, Result};

use mgr::compress::{Codec, MgardCompressor};
use mgr::coordinator::{Backend, Coordinator, JobMode, JobSpec};
use mgr::grid::{Hierarchy, Tensor};
use mgr::refactor::{class_norms, split_classes, Refactorer};
use mgr::runtime::EngineHandle;
use mgr::sim::GrayScott;
use mgr::simgpu::{ClusterModel, DeviceSpec};
use mgr::storage::{ProgressiveReader, ProgressiveWriter};
use mgr::util::cli::Args;
use mgr::util::rng::Rng;
use mgr::util::stats::{linf, time};

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn load_field(args: &Args) -> Result<Tensor<f64>> {
    let shape = args.get_shape("shape", &[33, 33, 33])?;
    match args.get_or("input", "grayscott").as_str() {
        "grayscott" => {
            if shape.len() != 3 || shape.iter().any(|&n| n != shape[0]) {
                bail!("grayscott input needs a cubic --shape NxNxN");
            }
            let steps = args.get_usize("steps", 200)?;
            let mut sim = GrayScott::new(shape[0], args.get_usize("seed", 7)? as u64);
            sim.step(steps);
            Ok(sim.v_field())
        }
        "random" => {
            let mut rng = Rng::new(args.get_usize("seed", 7)? as u64);
            Ok(Tensor::from_fn(&shape, |_| rng.normal()))
        }
        other => bail!("unknown --input '{other}' (grayscott|random)"),
    }
}

fn run(args: &Args) -> Result<()> {
    args.apply_parallelism()?;
    match args.subcommand.as_deref() {
        Some("info") => info(args),
        Some("refactor") => refactor(args),
        Some("retrieve") => retrieve(args),
        Some("compress") | Some("roundtrip") => compress(args),
        Some("serve") => serve(args),
        Some("pjrt-check") => pjrt_check(args),
        _ => {
            println!(
                "mgr — multigrid-based hierarchical data refactoring\n\n\
                 usage: mgr <subcommand> [options]\n\n\
                 subcommands:\n\
                 \x20 info                      artifact + device summary\n\
                 \x20 refactor   [--shape NxNxN --input grayscott|random]\n\
                 \x20            [--out f.mgr --eb 1e-3 --codec zlib|huff-rle]\n\
                 \x20 retrieve   --in f.mgr [--keep K | --error E] [--dump raw.bin]\n\
                 \x20 compress   [--shape NxNxN --eb 1e-3 --codec zlib|huff-rle]\n\
                 \x20 serve      [--jobs N --workers N --mode serial|coop|emb]\n\
                 \x20 pjrt-check [--artifacts DIR]\n\n\
                 global options (any subcommand):\n\
                 \x20 --threads N        intra-kernel worker count (0 = all cores)\n\
                 \x20 --par-threshold N  min elements before kernels fork\n\
                 \x20                    (0 = restore default, 1 = always fork)\n"
            );
            Ok(())
        }
    }
}

fn info(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    println!("== devices (analytic models, see DESIGN.md) ==");
    for d in [DeviceSpec::volta_v100(), DeviceSpec::turing_2080ti()] {
        let m = ClusterModel::new(d.clone(), 3, 9, 8);
        println!(
            "  {:<10}  mem {:>5.0} GB/s   refactor peak {:>5.1} GB/s",
            d.name,
            d.mem_bw / 1e9,
            m.theoretical_peak() / 1e9
        );
    }
    println!("== artifacts ({dir}) ==");
    match mgr::runtime::Manifest::load(format!("{dir}/manifest.json")) {
        Ok(m) => {
            for v in &m.variants {
                println!(
                    "  {:<40} {:?} {} levels={}",
                    v.name, v.shape, v.dtype, v.nlevels
                );
            }
        }
        Err(e) => println!("  (none: {e})"),
    }
    Ok(())
}

fn refactor(args: &Args) -> Result<()> {
    let data = load_field(args)?;
    let h = Hierarchy::uniform(data.shape());
    let mut t = data.clone();
    let (_, secs) = time(|| Refactorer::new(h.clone()).decompose(&mut t));
    let classes = split_classes(&t, &h);
    let norms = class_norms(&t, &h);
    println!(
        "decomposed {:?} ({} levels) in {:.1} ms — {:.2} GB/s",
        data.shape(),
        h.nlevels(),
        secs * 1e3,
        data.nbytes() as f64 / secs / 1e9
    );
    println!("{:<8} {:>12} {:>14} {:>14}", "class", "values", "bytes", "max|coef|");
    for (k, c) in classes.iter().enumerate() {
        println!(
            "{:<8} {:>12} {:>14} {:>14.3e}",
            k,
            c.len(),
            c.len() * 8,
            norms.linf[k]
        );
    }

    if let Some(out) = args.get("out") {
        let eb = args.get_f64("eb", 1e-3)?;
        let codec = parse_codec(args)?;
        let mut writer = ProgressiveWriter::<f64>::new(h.clone(), codec);
        let (header, secs) = time(|| writer.write_file(&data, eb, out));
        let header = header?;
        println!(
            "\nwrote container {out} ({} codec, eb {eb:.1e}) in {:.1} ms",
            codec.name(),
            secs * 1e3
        );
        println!(
            "{:<8} {:>12} {:>14} {:>14} {:>14}",
            "class", "values", "seg bytes", "L∞ after", "RMSE after"
        );
        for (k, s) in header.segments.iter().enumerate() {
            println!(
                "{:<8} {:>12} {:>14} {:>14.3e} {:>14.3e}",
                k, s.nvalues, s.bytes, s.linf, s.rmse
            );
        }
        let total = header.header_bytes() as u64 + header.payload_bytes();
        println!(
            "total {total} bytes ({:.2}x over raw {})",
            data.nbytes() as f64 / total as f64,
            data.nbytes()
        );
    }
    Ok(())
}

fn parse_codec(args: &Args) -> Result<Codec> {
    match args.get_or("codec", "zlib").as_str() {
        "zlib" => Ok(Codec::Zlib),
        "huff-rle" => Ok(Codec::HuffRle),
        other => bail!("unknown codec '{other}'"),
    }
}

fn retrieve(args: &Args) -> Result<()> {
    let path = args
        .get("in")
        .map(str::to_string)
        .or_else(|| args.positional.first().cloned())
        .ok_or_else(|| anyhow!("retrieve needs --in FILE (or a positional path)"))?;
    let buf = std::fs::read(&path).with_context(|| format!("reading container {path}"))?;
    // dispatch on the container's scalar width (f32 and f64 containers
    // are both readable)
    match mgr::storage::container::peek_dtype(&buf)? {
        4 => retrieve_typed::<f32>(args, &buf, &path),
        _ => retrieve_typed::<f64>(args, &buf, &path),
    }
}

fn retrieve_typed<T: mgr::util::Scalar>(args: &Args, buf: &[u8], path: &str) -> Result<()> {
    let mut reader = ProgressiveReader::<T>::open(buf)?;
    let header = reader.header().clone();
    println!(
        "container {path}: shape {:?}, {} levels, {} classes, {} codec, eb {:.1e}",
        header.shape,
        header.nlevels,
        header.nclasses(),
        header.codec.name(),
        header.quant.error_bound
    );
    println!("{:<8} {:>14} {:>14} {:>14}", "class", "seg bytes", "L∞ after", "RMSE after");
    for (k, s) in header.segments.iter().enumerate() {
        println!("{:<8} {:>14} {:>14.3e} {:>14.3e}", k, s.bytes, s.linf, s.rmse);
    }

    let keep = if let Some(e) = args.get("error") {
        let target: f64 = e
            .parse()
            .map_err(|_| anyhow!("--error expects a number, got '{e}'"))?;
        ensure!(
            target.is_finite() && target > 0.0,
            "--error must be positive and finite, got {target}"
        );
        let keep = header.select_keep(target);
        println!(
            "--error {target:.1e}: smallest satisfying prefix is {keep}/{} classes{}",
            header.nclasses(),
            if header.segments[keep - 1].linf > target {
                " (target unsatisfiable; keeping everything)"
            } else {
                ""
            }
        );
        keep
    } else {
        let keep = args.get_usize("keep", header.nclasses())?;
        if keep < 1 || keep > header.nclasses() {
            bail!("--keep must be in 1..={}, got {keep}", header.nclasses());
        }
        keep
    };

    let (tensor, secs) = time(|| reader.retrieve(keep));
    let tensor = tensor?;
    let read = header.prefix_bytes(keep);
    println!(
        "retrieved {keep}/{} classes ({read} of {} payload bytes, {:.1}%) in {:.1} ms \
         — recorded L∞ {:.3e}, RMSE {:.3e}",
        header.nclasses(),
        header.payload_bytes(),
        100.0 * read as f64 / header.payload_bytes() as f64,
        secs * 1e3,
        header.segments[keep - 1].linf,
        header.segments[keep - 1].rmse
    );

    if let Some(dump) = args.get("dump") {
        // always dumps f64 LE (f32 containers are widened)
        let mut raw = Vec::with_capacity(tensor.len() * 8);
        for v in tensor.data() {
            raw.extend_from_slice(&v.to_f64().to_le_bytes());
        }
        std::fs::write(dump, raw)?;
        println!("dumped {} little-endian f64 values to {dump}", tensor.len());
    }
    Ok(())
}

fn compress(args: &Args) -> Result<()> {
    let data = load_field(args)?;
    let eb = args.get_f64("eb", 1e-3)?;
    let codec = parse_codec(args)?;
    let h = Hierarchy::uniform(data.shape());
    let mut c = MgardCompressor::new(h, codec);
    let blob = c.compress(&data, eb)?;
    println!(
        "compressed {:?}: {} -> {} bytes (ratio {:.2}x) in {:.1} ms",
        data.shape(),
        blob.original_bytes,
        blob.payload.len(),
        blob.ratio(),
        c.stats.compress_total() * 1e3
    );
    println!(
        "  breakdown: decompose {:.1} ms, quantize {:.1} ms, {} {:.1} ms",
        c.stats.decompose_s * 1e3,
        c.stats.quantize_s * 1e3,
        codec.name(),
        c.stats.encode_s * 1e3
    );
    let back = c.decompress(&blob)?;
    let err = linf(back.data(), data.data());
    println!(
        "  decompressed in {:.1} ms; L∞ error {:.3e} (bound {eb:.1e}) — {}",
        c.stats.decompress_total() * 1e3,
        err,
        if err <= eb { "OK" } else { "VIOLATED" }
    );
    if err > eb {
        bail!("error bound violated");
    }
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let njobs = args.get_usize("jobs", 8)?;
    let workers = args.get_usize("workers", 4)?;
    let shape = args.get_shape("shape", &[33, 33, 33])?;
    let mode = match args.get_or("mode", "serial").as_str() {
        "serial" => JobMode::Serial,
        "coop" => JobMode::Cooperative { workers: 3 },
        "emb" => JobMode::Embarrassing { devices: 2 },
        other => bail!("unknown mode '{other}'"),
    };
    let mut rng = Rng::new(11);
    let jobs: Vec<JobSpec> = (0..njobs)
        .map(|i| JobSpec {
            name: format!("job{i}"),
            data: Tensor::from_fn(&shape, |_| rng.normal()),
            mode,
            error_bound: None,
            codec: Codec::Zlib,
        })
        .collect();
    let total_bytes: usize = jobs.iter().map(|j| j.data.nbytes()).sum();
    let coord = Coordinator::new(Backend::Native, workers);
    let (results, secs) = time(|| coord.run_batch(jobs));
    let ok = results.iter().filter(|r| r.is_ok()).count();
    println!(
        "served {ok}/{njobs} jobs ({} workers) in {:.1} ms — {:.2} GB/s aggregate",
        workers,
        secs * 1e3,
        total_bytes as f64 / secs / 1e9
    );
    for r in results {
        let r = r?;
        println!(
            "  {:<8} {:.1} ms  {:.2} GB/s",
            r.name,
            r.seconds * 1e3,
            r.throughput_gbps()
        );
    }
    Ok(())
}

fn pjrt_check(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let engine = EngineHandle::spawn(dir.into())?;
    let variants = engine.variants()?;
    println!("checking {} artifacts against the native core", variants.len());
    let mut checked = 0;
    for v in variants.iter().filter(|v| v.op == "decompose") {
        let shape = v.shape.clone();
        let h = Hierarchy::uniform(&shape);
        let mut rng = Rng::new(42);
        let err = if v.dtype == "float32" {
            let t = Tensor::from_fn(&shape, |_| rng.normal() as f32);
            let got = engine.run(&v.name, &t, &h.coords().to_vec())?;
            let mut want = t.clone();
            Refactorer::new(h.clone()).decompose(&mut want);
            linf(got.data(), want.data())
        } else {
            let t = Tensor::from_fn(&shape, |_| rng.normal());
            let got = engine.run(&v.name, &t, &h.coords().to_vec())?;
            let mut want = t.clone();
            Refactorer::new(h.clone()).decompose(&mut want);
            linf(got.data(), want.data())
        };
        let tol = if v.dtype == "float32" { 1e-3 } else { 1e-9 };
        println!("  {:<40} L∞(pjrt, native) = {err:.2e}", v.name);
        if err > tol {
            bail!("{}: PJRT and native disagree ({err})", v.name);
        }
        checked += 1;
    }
    println!("pjrt-check OK ({checked} decompose artifacts verified)");
    Ok(())
}
