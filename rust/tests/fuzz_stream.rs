//! Deterministic corrupt/truncated-input fuzzing of the MGRT
//! time-series parser, in the style of `tests/fuzz_shard.rs`. The
//! contract under test: a malformed stream yields a typed `Err` — it
//! must **never** panic, abort on a huge allocation, or read out of
//! bounds — and the commit protocol's torn-append tolerance must leave
//! every committed step readable bit-identically.

use std::io::{self, Cursor, Seek, SeekFrom, Write};
use std::sync::{Arc, Mutex};

use mgr::api::{AnyTensor, Fidelity, Series, Session};
use mgr::compress::Codec;
use mgr::grid::Tensor;
use mgr::sim::GrayScott;
use mgr::storage::stream::{
    StreamHeader, INDEPENDENT_PARENT, NSTEPS_OFFSET, STEP_RECORD_LEN, STREAM_FIXED_LEN,
};
use mgr::storage::{ShardHeader, ShardWriter};
use mgr::util::rng::Rng;

/// A cloneable in-memory sink: the writer keeps one handle, the test
/// keeps another to extract the produced bytes.
#[derive(Clone, Default)]
struct SharedCursor(Arc<Mutex<Cursor<Vec<u8>>>>);

impl SharedCursor {
    fn bytes(&self) -> Vec<u8> {
        self.0.lock().unwrap().get_ref().clone()
    }
}

impl Write for SharedCursor {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        self.0.lock().unwrap().write(buf)
    }
    fn flush(&mut self) -> io::Result<()> {
        self.0.lock().unwrap().flush()
    }
}

impl Seek for SharedCursor {
    fn seek(&mut self, pos: SeekFrom) -> io::Result<u64> {
        self.0.lock().unwrap().seek(pos)
    }
}

/// A real `.mgrt` produced through the public streaming path: Gray-Scott
/// snapshots of a 9³ grid, so the sample holds both independent and
/// (for smoothly evolving steps) delta-coded records.
fn sample_stream(nsteps: usize) -> Vec<u8> {
    let snaps = GrayScott::snapshots(9, 11, 30, nsteps, 2);
    let s = Session::builder()
        .shape(&[9, 9, 9])
        .error_bound(1e-3)
        .build()
        .unwrap();
    let shared = SharedCursor::default();
    let w = s.stream(shared.clone(), 2).unwrap();
    for t in &snaps {
        w.push(&AnyTensor::from(t.clone())).unwrap();
    }
    w.finish().unwrap();
    shared.bytes()
}

/// Open + exhaustively exercise a (possibly corrupt) stream buffer: the
/// header walk, every step's metadata, and every step's reconstruction.
/// Nothing here may panic; errors are fine.
fn exercise(buf: &[u8]) {
    let _ = StreamHeader::parse(buf);
    if let Ok(series) = Series::from_bytes(buf.to_vec()) {
        let n = series.nsteps() as u64;
        for t in 0..n {
            let _ = series.step(t);
            let _ = series.retrieve_step(t, Fidelity::Classes(1));
            let _ = series.retrieve_step(t, Fidelity::All);
        }
        assert!(series.retrieve_step(n, Fidelity::All).is_err());
    }
}

#[test]
fn truncation_sweep_over_every_prefix_length() {
    let bytes = sample_stream(3);
    // a stream truncated anywhere — mid-prelude, mid-record-header,
    // mid-payload — is rejected at open: the committed count pins the
    // exact extent every record must fit inside
    for len in 0..bytes.len() {
        assert!(
            StreamHeader::parse(&bytes[..len]).is_err(),
            "prefix of {len} bytes must be rejected"
        );
        assert!(
            Series::from_bytes(bytes[..len].to_vec()).is_err(),
            "prefix of {len} bytes must not open"
        );
    }
    exercise(&bytes); // the intact stream must fully retrieve
}

#[test]
fn bit_flips_across_the_metadata_never_panic() {
    let bytes = sample_stream(3);
    let header = StreamHeader::parse(&bytes).unwrap();
    // every bit of the prelude, every record header, and the head of
    // every embedded container payload
    let mut targets: Vec<usize> = (0..StreamHeader::prelude_bytes(3)).collect();
    for meta in &header.steps {
        let rec = meta.offset as usize - STEP_RECORD_LEN;
        targets.extend(rec..meta.offset as usize);
        targets.extend(meta.offset as usize..meta.offset as usize + 32);
    }
    for i in targets {
        for bit in 0..8 {
            let mut m = bytes.clone();
            m[i] ^= 1 << bit;
            // count-shrinking flips may validly succeed with fewer
            // steps; everything else must fail typed — never panic
            exercise(&m);
        }
    }
}

#[test]
fn random_mutations_never_panic() {
    let bytes = sample_stream(4);
    let mut rng = Rng::new(42);
    for _ in 0..500 {
        let mut m = bytes.clone();
        match rng.below(3) {
            0 => {
                let i = rng.below(m.len());
                m[i] ^= 1 << rng.below(8);
            }
            1 => {
                let i = rng.below(m.len());
                m[i] = rng.below(256) as u8;
            }
            _ => {
                let i = rng.below(m.len());
                let l = 1 + rng.below(16).min(m.len() - i - 1);
                m.drain(i..i + l);
            }
        }
        exercise(&m);
    }
}

#[test]
fn foreign_magic_and_garbage_rejected() {
    let mut rng = Rng::new(7);
    for len in [0usize, 1, 4, STREAM_FIXED_LEN, 64, 200, 1000] {
        let garbage: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        assert!(StreamHeader::parse(&garbage).is_err());
        assert!(Series::from_bytes(garbage).is_err());
    }
    // right magic, garbage tail
    let mut buf = b"MGRT".to_vec();
    buf.extend((0..200).map(|_| rng.below(256) as u8));
    assert!(StreamHeader::parse(&buf).is_err());

    // cross-format confusion fails closed in both directions: a shard is
    // not a stream, a stream is not a shard, a zip is neither
    let field = Tensor::<f64>::from_fn(&[9, 9], |idx| (idx[0] as f64 * 0.3).sin() + idx[1] as f64);
    let (shard, _) = ShardWriter::<f64>::new(Codec::Zlib, 2)
        .write(&field, 0, 2, 1e-3)
        .unwrap();
    assert!(StreamHeader::parse(&shard).is_err());
    let stream = sample_stream(1);
    assert!(ShardHeader::parse(&stream).is_err());
    assert!(StreamHeader::parse(b"PK\x03\x04 the rest of a zip file").is_err());
}

#[test]
fn out_of_range_parent_references_are_rejected() {
    let bytes = sample_stream(3);
    let header = StreamHeader::parse(&bytes).unwrap();
    // rewrite step 2's record header by hand: encoding at +8, parent at
    // +9..17 (see the format table in `storage::stream`)
    let rec = header.steps[2].offset as usize - STEP_RECORD_LEN;
    let patch = |enc: u8, parent: u64| {
        let mut m = bytes.clone();
        m[rec + 8] = enc;
        m[rec + 9..rec + 17].copy_from_slice(&parent.to_le_bytes());
        m
    };
    for (enc, parent, why) in [
        (1u8, 2u64, "delta parent == index"),
        (1, 5, "delta parent > index"),
        (1, INDEPENDENT_PARENT, "delta parent is the independent sentinel"),
        (0, 0, "independent step carrying a parent"),
        (2, INDEPENDENT_PARENT, "unknown encoding tag"),
    ] {
        let m = patch(enc, parent);
        assert!(StreamHeader::parse(&m).is_err(), "{why} must be rejected");
        exercise(&m);
    }
    // the index echo pins each record to its table position
    let mut m = bytes.clone();
    m[rec..rec + 8].copy_from_slice(&7u64.to_le_bytes());
    assert!(StreamHeader::parse(&m).is_err(), "echo mismatch must be rejected");
    exercise(&m);
    // a committed count past the real record extent is a truncation error
    let mut m = bytes.clone();
    m[NSTEPS_OFFSET as usize..NSTEPS_OFFSET as usize + 4].copy_from_slice(&4u32.to_le_bytes());
    assert!(StreamHeader::parse(&m).is_err(), "inflated count must be rejected");
    exercise(&m);
}

#[test]
fn torn_final_append_leaves_committed_steps_readable() {
    let bytes = sample_stream(4);
    let truth = Series::from_bytes(bytes.clone()).unwrap();

    // crash between the two commit flushes: step 3's record bytes are on
    // disk but the count patch never landed — exactly what rolling the
    // committed count back by one simulates
    let mut torn = bytes.clone();
    torn[NSTEPS_OFFSET as usize..NSTEPS_OFFSET as usize + 4]
        .copy_from_slice(&3u32.to_le_bytes());
    let h = StreamHeader::parse(&torn).unwrap();
    assert_eq!(h.nsteps(), 3, "the in-flight step must not exist");
    let series = Series::from_bytes(torn).unwrap();
    assert!(series.retrieve_step(3, Fidelity::All).is_err());
    for t in 0..3u64 {
        // committed steps — including delta chains — are bit-identical
        assert_eq!(
            series.retrieve_step(t, Fidelity::All).unwrap(),
            truth.retrieve_step(t, Fidelity::All).unwrap(),
            "step {t} after a torn final append"
        );
    }

    // a crash mid-record (arbitrary garbage tail) is equally invisible
    let mut garbled = bytes.clone();
    garbled[NSTEPS_OFFSET as usize..NSTEPS_OFFSET as usize + 4]
        .copy_from_slice(&3u32.to_le_bytes());
    garbled.truncate(bytes.len() - 11);
    garbled.extend_from_slice(b"\xff\xfftorn");
    let series = Series::from_bytes(garbled).unwrap();
    for t in 0..3u64 {
        assert_eq!(
            series.retrieve_step(t, Fidelity::All).unwrap(),
            truth.retrieve_step(t, Fidelity::All).unwrap(),
            "step {t} after a mid-record tear"
        );
    }
}
