//! Deterministic corrupt/truncated-input fuzzing of every byte-stream
//! decoder: `huffman::decode`, `rle::decode`, `varint::decode`, and the
//! progressive-container reader. The contract under test: a malformed
//! byte stream returns `Err` (or, where a truncation happens to leave a
//! self-consistent stream, the original data) — it must **never** panic,
//! abort on a huge allocation, or overflow.

use mgr::compress::{huffman, rle, varint, Codec};
use mgr::grid::{Hierarchy, Tensor};
use mgr::storage::{ProgressiveReader, ProgressiveWriter};
use mgr::util::rng::Rng;

/// Representative quantized-coefficient streams: sparse (long zero runs),
/// dense, adversarial magnitudes, and empty.
fn sample_streams() -> Vec<Vec<i64>> {
    let mut rng = Rng::new(42);
    let mut sparse = vec![0i64; 4000];
    for _ in 0..40 {
        let i = rng.below(4000);
        sparse[i] = (rng.normal() * 100.0) as i64;
    }
    let dense: Vec<i64> = (0..2000).map(|_| (rng.normal() * 1000.0) as i64).collect();
    vec![
        sparse,
        dense,
        vec![i64::MIN, i64::MAX, 0, -1, 1],
        vec![7],
        Vec::new(),
    ]
}

fn mutations(buf: &[u8], rng: &mut Rng, n: usize) -> Vec<Vec<u8>> {
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let mut m = buf.to_vec();
        if m.is_empty() {
            continue;
        }
        match rng.below(3) {
            0 => {
                // flip a random bit
                let i = rng.below(m.len());
                m[i] ^= 1 << rng.below(8);
            }
            1 => {
                // overwrite a random byte
                let i = rng.below(m.len());
                m[i] = rng.below(256) as u8;
            }
            _ => {
                // splice a random chunk out of the middle
                let i = rng.below(m.len());
                let l = 1 + rng.below(8).min(m.len() - i - 1);
                m.drain(i..i + l);
            }
        }
        out.push(m);
    }
    out
}

#[test]
fn varint_decoder_never_panics() {
    let mut rng = Rng::new(1);
    for vals in sample_streams() {
        let enc = varint::encode(&vals);
        assert_eq!(varint::decode(&enc).unwrap(), vals);
        // every truncation of a varint stream is malformed
        for len in 0..enc.len() {
            assert!(varint::decode(&enc[..len]).is_err(), "truncated to {len}");
        }
        for m in mutations(&enc, &mut rng, 200) {
            let _ = varint::decode(&m); // must not panic
        }
    }
}

#[test]
fn rle_decoder_never_panics() {
    let mut rng = Rng::new(2);
    for vals in sample_streams() {
        let enc = rle::encode(&vals);
        assert_eq!(rle::decode(&enc).unwrap(), vals);
        for len in 0..enc.len() {
            // a truncation either fails or (when only the trailing
            // zero-run token is cut after the stream is already complete)
            // still decodes to exactly the original values
            if let Ok(got) = rle::decode(&enc[..len]) {
                assert_eq!(got, vals, "truncated to {len}");
            }
        }
        for m in mutations(&enc, &mut rng, 200) {
            let _ = rle::decode(&m); // must not panic or huge-alloc
        }
    }
}

#[test]
fn huffman_decoder_never_panics() {
    let mut rng = Rng::new(3);
    let mut payloads: Vec<Vec<u8>> = sample_streams()
        .iter()
        .map(|v| rle::encode(v))
        .collect();
    payloads.push((0..4096).map(|_| rng.below(256) as u8).collect());
    for data in payloads {
        let enc = huffman::encode(&data);
        assert_eq!(huffman::decode(&enc).unwrap(), data);
        // dense sweep for small buffers, strided for large ones (each
        // truncated decode is O(len), so the full sweep is quadratic)
        let step = (enc.len() / 512).max(1);
        for len in (0..enc.len()).step_by(step) {
            if let Ok(got) = huffman::decode(&enc[..len]) {
                assert_eq!(got, data, "truncated to {len}");
            }
        }
        for m in mutations(&enc, &mut rng, 300) {
            let _ = huffman::decode(&m); // must not panic
        }
    }
}

#[test]
fn decoders_reject_random_garbage() {
    let mut rng = Rng::new(4);
    for len in [1usize, 8, 64, 137, 512, 4096] {
        for _ in 0..50 {
            let garbage: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            let _ = varint::decode(&garbage);
            let _ = rle::decode(&garbage);
            let _ = huffman::decode(&garbage);
            assert!(ProgressiveReader::<f64>::open(&garbage).is_err());
        }
    }
}

#[test]
fn container_reader_never_panics() {
    let field = Tensor::<f64>::from_fn(&[17, 17], |idx| {
        ((idx[0] as f64) * 0.37).sin() + ((idx[1] as f64) * 0.21).cos()
    });
    let h = Hierarchy::uniform(field.shape());
    let mut rng = Rng::new(5);
    for codec in [Codec::Zlib, Codec::HuffRle] {
        let mut w = ProgressiveWriter::<f64>::new(h.clone(), codec);
        let (container, _) = w.write(&field, 1e-3).unwrap();

        // full open + retrieve works
        let mut r = ProgressiveReader::<f64>::open(&container).unwrap();
        for keep in 1..=r.nclasses() {
            r.retrieve(keep).unwrap();
        }

        // every truncation is rejected (the segment table pins the exact
        // payload length)
        for len in 0..container.len() {
            assert!(
                ProgressiveReader::<f64>::open(&container[..len]).is_err(),
                "{codec:?} truncated to {len}"
            );
        }

        // random corruption: open may fail, or succeed with a payload
        // whose retrieval fails — neither path may panic
        for m in mutations(&container, &mut rng, 500) {
            if let Ok(mut r) = ProgressiveReader::<f64>::open(&m) {
                for keep in 1..=r.nclasses() {
                    let _ = r.retrieve(keep);
                }
            }
        }
    }
}
