//! End-to-end host calibration: the measured prune-and-profile loop must
//! install winners into the par layer's tuned registry, and those winners
//! must never be slower than the untuned default policy (the default is
//! always in the profiled set, so "tuned loses to default" cannot happen
//! by construction).
//!
//! Lives in an integration test (own process) because the tuned registry
//! is process-global: exercising the real install path here cannot race
//! the library's unit tests, which share one process.

use mgr::simgpu::{calibrate, measure_peak_gbps};
use mgr::util::par::{self, KernelClass};

#[test]
fn calibrate_installs_winners_no_slower_than_default() {
    par::clear_tuned();
    let target = 1usize << 12; // small: keeps the measured runs fast
    let rep = calibrate::<f64>(&[target]);

    // the roofline every bench row is normalized against
    assert!(
        rep.peak_gbps.is_finite() && rep.peak_gbps > 0.0,
        "peak bandwidth must be a positive finite measurement, got {}",
        rep.peak_gbps
    );

    // one calibration per kernel family
    assert_eq!(rep.kernels.len(), KernelClass::ALL.len());
    for class in KernelClass::ALL {
        assert!(
            rep.kernels.iter().any(|k| k.class == class),
            "missing calibration for {}",
            class.name()
        );
    }

    for k in &rep.kernels {
        let name = k.class.name();
        assert!(
            k.chosen_time.is_finite() && k.chosen_time > 0.0,
            "{name}: chosen_time"
        );
        assert!(
            k.default_time.is_finite() && k.default_time > 0.0,
            "{name}: default_time"
        );
        // the default policy is always profiled, so the winner can tie it
        // but never lose to it
        assert!(
            k.chosen_time <= k.default_time,
            "{name}: chosen {} slower than default {}",
            k.chosen_time,
            k.default_time
        );
        assert!(k.speedup() >= 1.0, "{name}: speedup {}", k.speedup());
        assert!(k.bytes_moved > 0, "{name}: bytes_moved");
        assert!(k.candidates_ranked >= 6, "{name}: candidate space too small");
        assert!(k.profiled >= 2, "{name}: must profile top picks + default");
        assert!(k.gbps() > 0.0, "{name}: throughput");
        assert!(k.pct_peak(rep.peak_gbps) > 0.0, "{name}: roofline position");

        // the winner must be queryable at the exact decision size...
        let got = par::tuned_for(k.class, k.elem_bytes, k.elems);
        assert_eq!(got, Some(k.chosen), "{name}: registry lookup");
        // ...and nearest-class fallback serves other sizes of the family
        assert!(
            par::tuned_for(k.class, k.elem_bytes, k.elems.saturating_mul(64)).is_some(),
            "{name}: nearest size-class fallback"
        );
    }

    // re-calibration overwrites rather than duplicates, and clearing
    // restores the untuned state
    let again = calibrate::<f64>(&[target]);
    assert_eq!(again.kernels.len(), KernelClass::ALL.len());
    par::clear_tuned();
    assert!(par::tuned_for(KernelClass::Gpk, 8, target).is_none());
}

#[test]
fn peak_measurement_is_positive_and_repeatable_in_magnitude() {
    let a = measure_peak_gbps();
    let b = measure_peak_gbps();
    assert!(a.is_finite() && a > 0.0);
    assert!(b.is_finite() && b > 0.0);
    // not a tight bound — machines share cores with other work — but two
    // back-to-back best-of-4 measurements should land within ~an order
    // of magnitude of each other if the harness is sane
    let ratio = if a > b { a / b } else { b / a };
    assert!(ratio < 10.0, "peak measurements disagree wildly: {a} vs {b}");
}
