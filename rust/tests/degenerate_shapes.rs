//! Degenerate-shape matrix: extent-1 axes, single-level hierarchies, and
//! the all-degenerate shapes that must fail with a typed build error
//! instead of panicking deep inside a kernel.
//!
//! Size-1 axes are legal everywhere (the per-dimension operators collapse
//! to 1×1 identity factors of the tensor product), as long as at least
//! one axis actually refines.

use mgr::api::{AnyTensor, Dtype, Fidelity, Session};
use mgr::grid::Tensor;

/// Smooth deterministic field with O(1) values on any shape.
fn field(shape: &[usize], dtype: Dtype) -> AnyTensor {
    let f: AnyTensor = Tensor::<f64>::from_fn(shape, |idx| {
        idx.iter()
            .enumerate()
            .map(|(d, &i)| ((d as f64 + 1.3) * i as f64 * 0.21).sin())
            .product::<f64>()
            + 0.25
    })
    .into();
    f.cast(dtype)
}

#[test]
fn extent_one_axes_roundtrip_end_to_end() {
    let shapes: [&[usize]; 5] = [&[1, 65], &[65, 1], &[1, 33, 1], &[5, 1, 9], &[1, 1, 9]];
    let eb = 1e-4;
    for shape in shapes {
        let session = Session::builder()
            .shape(shape)
            .dtype(Dtype::F64)
            .error_bound(eb)
            .build()
            .unwrap_or_else(|e| panic!("{shape:?}: build failed: {e}"));
        let data = field(shape, Dtype::F64);
        let refactored = session
            .refactor(&data)
            .unwrap_or_else(|e| panic!("{shape:?}: refactor failed: {e}"));
        assert_eq!(refactored.shape(), shape);

        let full = session.retrieve(&refactored, Fidelity::All).unwrap();
        let err = full.linf_to(&data).unwrap();
        assert!(err <= eb * (1.0 + 1e-6) + 1e-12, "{shape:?}: err {err} > {eb}");

        // every coarser prefix reconstructs without panicking, with
        // non-increasing error
        let mut last = f64::INFINITY;
        for keep in 1..=refactored.nclasses() {
            let approx = session.retrieve(&refactored, Fidelity::Classes(keep)).unwrap();
            let e = approx.linf_to(&data).unwrap();
            assert!(
                e <= last * (1.0 + 1e-6) + 1e-12,
                "{shape:?} keep={keep}: error increased {last} -> {e}"
            );
            last = e;
        }
    }
}

#[test]
fn smallest_refactorable_axis_roundtrips() {
    for shape in [&[3usize][..], &[3, 1][..]] {
        let eb = 1e-6;
        let session = Session::builder().shape(shape).error_bound(eb).build().unwrap();
        let data = field(shape, Dtype::F64);
        let refactored = session.refactor(&data).unwrap();
        let full = session.retrieve(&refactored, Fidelity::All).unwrap();
        let err = full.linf_to(&data).unwrap();
        assert!(err <= eb * (1.0 + 1e-6) + 1e-12, "{shape:?}: err {err}");
    }
}

#[test]
fn all_degenerate_shapes_fail_with_typed_error() {
    for shape in [&[1usize][..], &[1, 1][..], &[1, 1, 1][..]] {
        let err = Session::builder()
            .shape(shape)
            .build()
            .err()
            .unwrap_or_else(|| panic!("{shape:?}: all-size-1 shape must not build"));
        let msg = err.to_string();
        assert!(
            msg.contains("no refactorable dimension"),
            "{shape:?}: unhelpful error: {msg}"
        );
    }
}

#[test]
fn non_power_of_two_shapes_fail_with_typed_error() {
    for shape in [&[6usize][..], &[2][..], &[1, 6][..], &[33, 4][..]] {
        let err = Session::builder()
            .shape(shape)
            .build()
            .err()
            .unwrap_or_else(|| panic!("{shape:?}: invalid shape must not build"));
        let msg = err.to_string();
        assert!(
            msg.contains("not refactorable"),
            "{shape:?}: unhelpful error: {msg}"
        );
    }
}

#[test]
fn single_level_hierarchy_roundtrips_and_bad_nlevels_is_rejected() {
    let shape = [33usize];
    let eb = 1e-5;
    let session = Session::builder()
        .shape(&shape)
        .nlevels(1)
        .error_bound(eb)
        .build()
        .unwrap();
    let data = field(&shape, Dtype::F64);
    let refactored = session.refactor(&data).unwrap();
    let full = session.retrieve(&refactored, Fidelity::All).unwrap();
    let err = full.linf_to(&data).unwrap();
    assert!(err <= eb * (1.0 + 1e-6) + 1e-12, "single level: err {err}");

    // out-of-range level counts fail at build, naming the valid range
    for bad in [0usize, 99] {
        let err = Session::builder()
            .shape(&shape)
            .nlevels(bad)
            .build()
            .err()
            .unwrap_or_else(|| panic!("nlevels {bad} must not build"));
        assert!(err.to_string().contains("nlevels"), "nlevels {bad}: {err}");
    }
}
