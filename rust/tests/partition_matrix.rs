//! Property-style matrix over the §3.6 partitioning: for every
//! axis × 1D/2D/3D shape × f32/f64 × valid part count (including the
//! 2-part and max-part boundaries), extraction followed by reassembly
//! reproduces the original tensor **bitwise**, and every block is
//! itself refactorable (`max_levels` is `Some` — the property that
//! makes embarrassing-parallel refactoring possible at all). The same
//! matrix runs for single-axis slabs and for N-D block grids, and the
//! `[p, 1, 1, …]` degenerate grid is checked against the slab
//! partition extent-for-extent.

use mgr::coordinator::{
    assemble_blocks, assemble_slabs, extract_block, extract_slab, partition_grid,
    partition_slabs, BlockExtent, Slab,
};
use mgr::grid::{max_levels, Tensor};
use mgr::util::rng::Rng;
use mgr::util::Scalar;

/// Every part count the axis supports: divisors of `n - 1` whose
/// quotient is `2^j`, `j >= 1`.
fn valid_parts(n: usize) -> Vec<usize> {
    (1..n)
        .filter(|&p| {
            let interior = n - 1;
            interior % p == 0 && {
                let seg = interior / p;
                seg >= 2 && seg.is_power_of_two()
            }
        })
        .collect()
}

fn roundtrip_case<T: Scalar>(shape: &[usize], axis: usize, parts: usize, seed: u64) {
    let mut rng = Rng::new(seed);
    let t = Tensor::<T>::from_fn(shape, |_| T::from_f64(rng.normal()));
    let slabs = partition_slabs(shape, axis, parts)
        .unwrap_or_else(|e| panic!("{shape:?} axis {axis} parts {parts}: {e}"));
    assert_eq!(slabs.len(), parts, "{shape:?} axis {axis}");

    // coverage: slabs tile the axis sharing boundary nodes
    assert_eq!(slabs[0].start, 0);
    for w in slabs.windows(2) {
        assert_eq!(w[1].start, w[0].start + w[0].len - 1, "shared boundary node");
    }
    let last = slabs.last().unwrap();
    assert_eq!(last.start + last.len, shape[axis]);

    let mut parts_data: Vec<(Slab, Tensor<T>)> = Vec::new();
    for s in &slabs {
        let block = extract_slab(&t, s);
        // per-slab refactorability: every dimension of every slab is 2^k+1
        assert!(
            max_levels(block.shape()).is_some(),
            "slab {s:?} of {shape:?} has unrefactorable shape {:?}",
            block.shape()
        );
        assert_eq!(block.shape()[axis], s.len);
        parts_data.push((s.clone(), block));
    }

    // bitwise roundtrip (exact equality, not an epsilon)
    let back = assemble_slabs(shape, &parts_data);
    assert_eq!(back, t, "{shape:?} axis {axis} parts {parts}");
}

#[test]
fn matrix_roundtrips_bitwise_for_every_axis_shape_dtype_and_parts() {
    let shapes: &[&[usize]] = &[
        &[17],
        &[33],
        &[17, 9],
        &[9, 33],
        &[9, 9, 17],
        &[17, 5, 9],
    ];
    let mut seed = 1;
    for shape in shapes {
        for axis in 0..shape.len() {
            let parts = valid_parts(shape[axis]);
            assert!(!parts.is_empty(), "{shape:?} axis {axis} supports no partition");
            // the interesting boundaries plus everything in between
            assert!(parts.contains(&2) || shape[axis] == 5, "{shape:?} axis {axis}");
            for &p in &parts {
                seed += 1;
                roundtrip_case::<f64>(shape, axis, p, seed);
                roundtrip_case::<f32>(shape, axis, p, seed + 1000);
            }
        }
    }
}

#[test]
fn two_part_and_max_part_boundaries() {
    // n = 33: 2 parts of interior 16, and the maximum 16 parts of
    // interior 2 — the thinnest legal slab (3 nodes)
    let shape = [33usize, 9];
    for parts in [2usize, 16] {
        let slabs = partition_slabs(&shape, 0, parts).unwrap();
        assert_eq!(slabs.len(), parts);
        let seg = 32 / parts;
        for s in &slabs {
            assert_eq!(s.len, seg + 1);
            assert!(max_levels(&[s.len]).is_some());
        }
    }
    // one past the maximum is rejected (interior would be 1 node)
    assert!(partition_slabs(&shape, 0, 32).is_err());
}

fn grid_roundtrip_case<T: Scalar>(shape: &[usize], grid: &[usize], seed: u64) {
    let mut rng = Rng::new(seed);
    let t = Tensor::<T>::from_fn(shape, |_| T::from_f64(rng.normal()));
    let extents = partition_grid(shape, grid)
        .unwrap_or_else(|e| panic!("{shape:?} grid {grid:?}: {e}"));
    assert_eq!(extents.len(), grid.iter().product::<usize>(), "{shape:?} grid {grid:?}");

    let mut parts: Vec<(BlockExtent, Tensor<T>)> = Vec::new();
    for e in &extents {
        let block = extract_block(&t, e);
        // per-block refactorability: every dimension of every block is 2^k+1
        assert!(
            max_levels(block.shape()).is_some(),
            "block {e:?} of {shape:?} has unrefactorable shape {:?}",
            block.shape()
        );
        assert_eq!(block.shape(), e.len.as_slice());
        parts.push((e.clone(), block));
    }

    // bitwise roundtrip (exact equality, not an epsilon)
    let back = assemble_blocks(shape, &parts);
    assert_eq!(back, t, "{shape:?} grid {grid:?}");
}

#[test]
fn grid_matrix_roundtrips_bitwise_for_every_shape_dtype_and_grid() {
    // all-axes-2^k+1 shapes (grid partitioning validates every axis)
    let shapes: &[&[usize]] = &[&[17], &[33], &[17, 9], &[9, 33], &[5, 9, 17], &[9, 9, 9]];
    let mut seed = 5000;
    for shape in shapes {
        // a few valid part counts per axis, then the full cross product
        let per_axis: Vec<Vec<usize>> = shape
            .iter()
            .map(|&n| valid_parts(n).into_iter().take(3).collect())
            .collect();
        assert!(per_axis.iter().all(|p| !p.is_empty()), "{shape:?}");
        let mut pick = vec![0usize; shape.len()];
        loop {
            let grid: Vec<usize> = pick.iter().zip(&per_axis).map(|(&i, p)| p[i]).collect();
            seed += 2;
            grid_roundtrip_case::<f64>(shape, &grid, seed);
            grid_roundtrip_case::<f32>(shape, &grid, seed + 1);
            let mut done = true;
            for d in (0..pick.len()).rev() {
                pick[d] += 1;
                if pick[d] < per_axis[d].len() {
                    done = false;
                    break;
                }
                pick[d] = 0;
            }
            if done {
                break;
            }
        }
    }
}

#[test]
fn degenerate_grid_matches_the_slab_partition() {
    // [p, 1] (and [1, p]) grids must produce extent-for-extent the same
    // decomposition as the slab partitioner on that axis
    let shape = [33usize, 9];
    for axis in 0..2 {
        for p in [2usize, 4] {
            let slabs = partition_slabs(&shape, axis, p).unwrap();
            let mut gridspec = vec![1usize; 2];
            gridspec[axis] = p;
            let extents = partition_grid(&shape, &gridspec).unwrap();
            assert_eq!(extents.len(), slabs.len());
            for (e, s) in extents.iter().zip(&slabs) {
                let mut start = vec![0usize; 2];
                let mut len = shape.to_vec();
                start[axis] = s.start;
                len[axis] = s.len;
                assert_eq!(e.start, start, "axis {axis} parts {p}");
                assert_eq!(e.len, len, "axis {axis} parts {p}");
            }
        }
    }
}

#[test]
fn boundary_grids_and_rejections() {
    // [17, 9]: the maximal grid has 2-node interiors on both axes — the
    // thinnest legal blocks (3 nodes per side)
    grid_roundtrip_case::<f64>(&[17, 9], &[8, 4], 99);
    grid_roundtrip_case::<f32>(&[17, 9], &[8, 4], 100);
    // one past the maximum on either axis is rejected
    assert!(partition_grid(&[17, 9], &[16, 4]).is_err());
    assert!(partition_grid(&[17, 9], &[8, 8]).is_err());
    // non-dividing part counts are rejected
    assert!(partition_grid(&[17, 9], &[3, 1]).is_err());
    // rank mismatches are rejected with a typed error, never a panic
    assert!(partition_grid(&[17, 9], &[2]).is_err());
    assert!(partition_grid(&[17, 9], &[2, 2, 2]).is_err());
    assert!(partition_grid(&[], &[]).is_err());
}

#[test]
fn single_block_grid_is_the_identity_partition() {
    let shape = [17usize, 9];
    let mut rng = Rng::new(13);
    let t = Tensor::<f64>::from_fn(&shape, |_| rng.normal());
    let extents = partition_grid(&shape, &[1, 1]).unwrap();
    assert_eq!(extents.len(), 1);
    assert_eq!(extents[0].start, vec![0, 0]);
    assert_eq!(extents[0].len, vec![17, 9]);
    let block = extract_block(&t, &extents[0]);
    assert_eq!(block, t, "one block is the whole domain, bitwise");
}

#[test]
fn single_part_is_the_identity_partition() {
    let shape = [17usize, 9];
    let mut rng = Rng::new(7);
    let t = Tensor::<f64>::from_fn(&shape, |_| rng.normal());
    let slabs = partition_slabs(&shape, 0, 1).unwrap();
    assert_eq!(slabs.len(), 1);
    assert_eq!(slabs[0].len, 17);
    let block = extract_slab(&t, &slabs[0]);
    assert_eq!(block, t, "one slab is the whole domain, bitwise");
}
