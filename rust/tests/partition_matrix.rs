//! Property-style matrix over the §3.6 slab partitioning: for every
//! axis × 1D/2D/3D shape × f32/f64 × valid part count (including the
//! 2-part and max-part boundaries), extraction followed by reassembly
//! reproduces the original tensor **bitwise**, and every slab is itself
//! refactorable (`max_levels` is `Some` — the property that makes
//! embarrassing-parallel refactoring possible at all).

use mgr::coordinator::{assemble_slabs, extract_slab, partition_slabs, Slab};
use mgr::grid::{max_levels, Tensor};
use mgr::util::rng::Rng;
use mgr::util::Scalar;

/// Every part count the axis supports: divisors of `n - 1` whose
/// quotient is `2^j`, `j >= 1`.
fn valid_parts(n: usize) -> Vec<usize> {
    (1..n)
        .filter(|&p| {
            let interior = n - 1;
            interior % p == 0 && {
                let seg = interior / p;
                seg >= 2 && seg.is_power_of_two()
            }
        })
        .collect()
}

fn roundtrip_case<T: Scalar>(shape: &[usize], axis: usize, parts: usize, seed: u64) {
    let mut rng = Rng::new(seed);
    let t = Tensor::<T>::from_fn(shape, |_| T::from_f64(rng.normal()));
    let slabs = partition_slabs(shape, axis, parts)
        .unwrap_or_else(|e| panic!("{shape:?} axis {axis} parts {parts}: {e}"));
    assert_eq!(slabs.len(), parts, "{shape:?} axis {axis}");

    // coverage: slabs tile the axis sharing boundary nodes
    assert_eq!(slabs[0].start, 0);
    for w in slabs.windows(2) {
        assert_eq!(w[1].start, w[0].start + w[0].len - 1, "shared boundary node");
    }
    let last = slabs.last().unwrap();
    assert_eq!(last.start + last.len, shape[axis]);

    let mut parts_data: Vec<(Slab, Tensor<T>)> = Vec::new();
    for s in &slabs {
        let block = extract_slab(&t, s);
        // per-slab refactorability: every dimension of every slab is 2^k+1
        assert!(
            max_levels(block.shape()).is_some(),
            "slab {s:?} of {shape:?} has unrefactorable shape {:?}",
            block.shape()
        );
        assert_eq!(block.shape()[axis], s.len);
        parts_data.push((s.clone(), block));
    }

    // bitwise roundtrip (exact equality, not an epsilon)
    let back = assemble_slabs(shape, &parts_data);
    assert_eq!(back, t, "{shape:?} axis {axis} parts {parts}");
}

#[test]
fn matrix_roundtrips_bitwise_for_every_axis_shape_dtype_and_parts() {
    let shapes: &[&[usize]] = &[
        &[17],
        &[33],
        &[17, 9],
        &[9, 33],
        &[9, 9, 17],
        &[17, 5, 9],
    ];
    let mut seed = 1;
    for shape in shapes {
        for axis in 0..shape.len() {
            let parts = valid_parts(shape[axis]);
            assert!(!parts.is_empty(), "{shape:?} axis {axis} supports no partition");
            // the interesting boundaries plus everything in between
            assert!(parts.contains(&2) || shape[axis] == 5, "{shape:?} axis {axis}");
            for &p in &parts {
                seed += 1;
                roundtrip_case::<f64>(shape, axis, p, seed);
                roundtrip_case::<f32>(shape, axis, p, seed + 1000);
            }
        }
    }
}

#[test]
fn two_part_and_max_part_boundaries() {
    // n = 33: 2 parts of interior 16, and the maximum 16 parts of
    // interior 2 — the thinnest legal slab (3 nodes)
    let shape = [33usize, 9];
    for parts in [2usize, 16] {
        let slabs = partition_slabs(&shape, 0, parts).unwrap();
        assert_eq!(slabs.len(), parts);
        let seg = 32 / parts;
        for s in &slabs {
            assert_eq!(s.len, seg + 1);
            assert!(max_levels(&[s.len]).is_some());
        }
    }
    // one past the maximum is rejected (interior would be 1 node)
    assert!(partition_slabs(&shape, 0, 32).is_err());
}

#[test]
fn single_part_is_the_identity_partition() {
    let shape = [17usize, 9];
    let mut rng = Rng::new(7);
    let t = Tensor::<f64>::from_fn(&shape, |_| rng.normal());
    let slabs = partition_slabs(&shape, 0, 1).unwrap();
    assert_eq!(slabs.len(), 1);
    assert_eq!(slabs[0].len, 17);
    let block = extract_slab(&t, &slabs[0]);
    assert_eq!(block, t, "one slab is the whole domain, bitwise");
}
