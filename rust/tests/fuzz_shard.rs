//! Deterministic corrupt/truncated-input fuzzing of the MGRS shard
//! index parser, in the style of `tests/fuzz_decoders.rs`. The contract
//! under test: a malformed shard yields a typed `Err` — it must
//! **never** panic, abort on a huge allocation, or read out of bounds —
//! and a corrupt *block* must not poison retrieval of any other block.

use std::io::Cursor;

use mgr::compress::Codec;
use mgr::grid::Tensor;
use mgr::storage::shard::{shard_var_len, SHARD_FIXED_LEN};
use mgr::storage::{ShardHeader, ShardReader, ShardWriter};
use mgr::util::rng::Rng;

fn sample_shard(codec: Codec, blocks: usize) -> (Vec<u8>, ShardHeader) {
    let field = Tensor::<f64>::from_fn(&[17, 9], |idx| {
        ((idx[0] as f64) * 0.37).sin() + ((idx[1] as f64) * 0.21).cos()
    });
    let w = ShardWriter::<f64>::new(codec, 2);
    w.write(&field, 0, blocks, 1e-3).unwrap()
}

fn sample_grid_shard(codec: Codec) -> (Vec<u8>, ShardHeader) {
    let field = Tensor::<f64>::from_fn(&[17, 9], |idx| {
        ((idx[0] as f64) * 0.37).sin() + ((idx[1] as f64) * 0.21).cos()
    });
    let w = ShardWriter::<f64>::new(codec, 2);
    w.write_grid(&field, &[2, 2], 1e-3).unwrap()
}

/// A hand-built, well-formed **v1** (single-axis slab) index over
/// [17, 9]: two slabs on axis 0, 40-byte placeholder payloads.
fn v1_stream() -> Vec<u8> {
    let mut b = Vec::new();
    b.extend_from_slice(b"MGRS");
    b.extend_from_slice(&1u16.to_le_bytes());
    b.push(8); // f64
    b.push(0); // partition axis (v1 meaning of byte 7)
    b.push(2); // ndim
    b.push(0); // reserved
    b.extend_from_slice(&2u16.to_le_bytes()); // nblocks
    for d in [17u64, 9] {
        b.extend_from_slice(&d.to_le_bytes());
    }
    let hlen = (SHARD_FIXED_LEN + 8 * 2 + 32 * 2) as u64;
    for (start, len, off) in [(0u64, 9u64, hlen), (8, 9, hlen + 40)] {
        b.extend_from_slice(&start.to_le_bytes());
        b.extend_from_slice(&len.to_le_bytes());
        b.extend_from_slice(&off.to_le_bytes());
        b.extend_from_slice(&40u64.to_le_bytes());
    }
    b.extend(std::iter::repeat(0u8).take(80)); // placeholder payloads
    b
}

/// Open + exhaustively exercise a (possibly corrupt) shard buffer: the
/// index parse, every block open, and every retrieval prefix. Nothing
/// here may panic; errors are fine.
fn exercise(buf: &[u8]) {
    let _ = ShardHeader::parse(buf);
    let _ = shard_var_len(buf);
    if let Ok(r) = ShardReader::open(Cursor::new(buf.to_vec())) {
        for k in 0..r.nblocks() {
            if let Ok(lazy) = r.lazy_block::<f64>(k) {
                for keep in 1..=lazy.nclasses() {
                    let _ = lazy.retrieve(keep);
                }
            }
            // the wrong-dtype path must also stay total
            let _ = r.lazy_block::<f32>(k).is_err();
        }
    }
}

#[test]
fn truncation_sweep_over_every_prefix_length() {
    for codec in [Codec::Zlib, Codec::HuffRle] {
        let (bytes, _) = sample_shard(codec, 2);
        // a shard truncated anywhere — mid-prelude, mid-table, mid-block
        // — is rejected at open (the index pins the exact payload size)
        for len in 0..bytes.len() {
            assert!(
                ShardReader::open(Cursor::new(bytes[..len].to_vec())).is_err(),
                "{codec:?} truncated to {len} bytes must be rejected"
            );
            assert!(ShardHeader::parse(&bytes[..len]).is_err(), "{codec:?} len {len}");
        }
        exercise(&bytes); // the intact shard must fully retrieve
    }
}

#[test]
fn bit_flips_across_the_index_never_panic() {
    let (bytes, header) = sample_shard(Codec::Zlib, 2);
    // every bit of the index region, plus a tail of payload bytes
    let probe = header.header_bytes() + 64.min(bytes.len() - header.header_bytes());
    for i in 0..probe {
        for bit in 0..8 {
            let mut m = bytes.clone();
            m[i] ^= 1 << bit;
            exercise(&m);
        }
    }
}

#[test]
fn random_mutations_never_panic() {
    let (bytes, _) = sample_shard(Codec::HuffRle, 4);
    let mut rng = Rng::new(42);
    for _ in 0..500 {
        let mut m = bytes.clone();
        match rng.below(3) {
            0 => {
                let i = rng.below(m.len());
                m[i] ^= 1 << rng.below(8);
            }
            1 => {
                let i = rng.below(m.len());
                m[i] = rng.below(256) as u8;
            }
            _ => {
                let i = rng.below(m.len());
                let l = 1 + rng.below(16).min(m.len() - i - 1);
                m.drain(i..i + l);
            }
        }
        exercise(&m);
    }
}

#[test]
fn foreign_magic_and_garbage_rejected() {
    let mut rng = Rng::new(7);
    for len in [0usize, 1, 4, SHARD_FIXED_LEN, 64, 200, 1000] {
        let garbage: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        assert!(ShardReader::open(Cursor::new(garbage.clone())).is_err());
        assert!(ShardHeader::parse(&garbage).is_err());
    }
    // right magic, garbage tail
    let mut buf = b"MGRS".to_vec();
    buf.extend((0..200).map(|_| rng.below(256) as u8));
    assert!(ShardReader::open(Cursor::new(buf)).is_err());
    // a zip is not a shard
    assert!(ShardHeader::parse(b"PK\x03\x04 the rest of a zip file").is_err());
}

#[test]
fn offset_tables_pointing_past_eof_are_rejected() {
    let (bytes, header) = sample_shard(Codec::Zlib, 2);
    let ndim = header.shape.len();
    // v2 geometry: shape + grid dims precede the table; each entry is
    // start[d]×ndim, len[d]×ndim, offset, bytes
    let table = SHARD_FIXED_LEN + 16 * ndim;
    let entry = 16 * ndim + 16;
    for k in 0..header.nblocks() {
        for field in [16 * ndim, 16 * ndim + 8] {
            for huge in [u64::MAX, bytes.len() as u64 + 1, 1 << 40] {
                let mut m = bytes.clone();
                let pos = table + entry * k + field;
                m[pos..pos + 8].copy_from_slice(&huge.to_le_bytes());
                assert!(
                    ShardHeader::parse(&m).is_err() || ShardReader::open(Cursor::new(m.clone())).is_err(),
                    "block {k} field +{field} = {huge} must not open"
                );
                exercise(&m);
            }
        }
    }
}

#[test]
fn grid_dims_disagreeing_with_the_table_are_rejected() {
    let (bytes, header) = sample_grid_shard(Codec::Zlib);
    assert_eq!(header.grid, vec![2, 2]);
    let ndim = header.shape.len();
    let gpos = SHARD_FIXED_LEN + 8 * ndim; // grid dims sit right after the shape
    for d in 0..ndim {
        for bad in [0u64, 3, 5, 4096, u64::MAX] {
            let mut m = bytes.clone();
            m[gpos + 8 * d..gpos + 8 * d + 8].copy_from_slice(&bad.to_le_bytes());
            assert!(
                ShardHeader::parse(&m).is_err(),
                "grid dim {bad} on axis {d} must be rejected"
            );
            exercise(&m);
        }
    }
    // a plausible-but-wrong grid — right block count, wrong tiling —
    // dies on the canonical-extent check, not the product check
    let mut m = bytes.clone();
    m[gpos..gpos + 8].copy_from_slice(&4u64.to_le_bytes());
    m[gpos + 8..gpos + 16].copy_from_slice(&1u64.to_le_bytes());
    assert!(ShardHeader::parse(&m).is_err(), "[4, 1] relabel of a [2, 2] table");
    exercise(&m);
}

#[test]
fn overlapping_or_gapped_extents_are_rejected() {
    let (bytes, header) = sample_grid_shard(Codec::HuffRle);
    let ndim = header.shape.len();
    let table = SHARD_FIXED_LEN + 16 * ndim;
    let entry = 16 * ndim + 16;
    // nudge every start/len coordinate of every block by ±1: each such
    // mutation overlaps or gaps the tiling and must fail the
    // canonical-extent check — fail closed, never panic
    for k in 0..header.nblocks() {
        for field in (0..16 * ndim).step_by(8) {
            for delta in [1i64, -1] {
                let mut m = bytes.clone();
                let pos = table + entry * k + field;
                let v = u64::from_le_bytes(m[pos..pos + 8].try_into().unwrap());
                let nv = v.wrapping_add(delta as u64);
                m[pos..pos + 8].copy_from_slice(&nv.to_le_bytes());
                assert!(
                    ShardHeader::parse(&m).is_err(),
                    "block {k} entry byte +{field} nudged by {delta} must be rejected"
                );
                exercise(&m);
            }
        }
    }
}

#[test]
fn v1_indexes_parse_onto_a_degenerate_grid() {
    let v1 = v1_stream();
    let (h, hlen) = ShardHeader::parse_prefix(&v1).unwrap();
    assert_eq!(hlen, SHARD_FIXED_LEN + 8 * 2 + 32 * 2);
    assert_eq!(h.grid, vec![2, 1], "axis-0 slabs become a [parts, 1] grid");
    assert_eq!(h.blocks[0].start, vec![0, 0]);
    assert_eq!(h.blocks[0].len, vec![9, 9]);
    assert_eq!(h.blocks[1].start, vec![8, 0]);
    assert_eq!(h.blocks[1].len, vec![9, 9]);
    assert_eq!(ShardHeader::parse(&v1).unwrap().0.grid, vec![2, 1]);
    // reserialization always writes v2, whose table is strictly longer
    assert_eq!(h.to_bytes().len(), h.header_bytes());
    assert!(h.header_bytes() > hlen);
}

#[test]
fn version_byte_flips_fail_closed() {
    // a v1 stream relabeled version 2 lacks the grid dims the v2 table
    // starts with — the first "grid dim" it reads is block 0's start
    let mut m = v1_stream();
    m[4..6].copy_from_slice(&2u16.to_le_bytes());
    assert!(ShardHeader::parse(&m).is_err(), "v1 table as v2 must be rejected");
    exercise(&m);

    // ... and a v2 stream relabeled version 1 misparses its grid dims as
    // the first slab entry — also rejected, never panicking
    let (v2, _) = sample_grid_shard(Codec::Zlib);
    let mut m = v2.clone();
    m[4..6].copy_from_slice(&1u16.to_le_bytes());
    assert!(ShardHeader::parse(&m).is_err(), "v2 table as v1 must be rejected");
    exercise(&m);

    // unknown future versions are rejected up front
    for ver in [0u16, 3, 7, u16::MAX] {
        let mut m = v2.clone();
        m[4..6].copy_from_slice(&ver.to_le_bytes());
        assert!(ShardHeader::parse(&m).is_err(), "version {ver} must be rejected");
        exercise(&m);
    }
}

#[test]
fn truncated_v2_headers_fail_closed() {
    let (bytes, header) = sample_grid_shard(Codec::Zlib);
    // every prefix of the v2 index region — mid-prelude, mid-shape,
    // mid-grid, mid-table — is a typed error
    for len in 0..header.header_bytes() {
        assert!(ShardHeader::parse(&bytes[..len]).is_err(), "prefix {len}");
        assert!(ShardHeader::parse_prefix(&bytes[..len]).is_err(), "prefix {len}");
        exercise(&bytes[..len]);
    }
    // the bare index (no payloads) satisfies parse_prefix but not the
    // full payload-accounting parse
    let hdr = &bytes[..header.header_bytes()];
    assert!(ShardHeader::parse_prefix(hdr).is_ok());
    assert!(ShardHeader::parse(hdr).is_err());
}

#[test]
fn corrupt_block_is_isolated_from_the_others() {
    let (bytes, header) = sample_shard(Codec::Zlib, 4);
    let clean = ShardReader::open(Cursor::new(bytes.clone())).unwrap();
    for victim in 0..header.nblocks() {
        // clobber the victim's MGRC magic: the index still parses, the
        // victim fails at its own open, everyone else is bit-identical
        let mut m = bytes.clone();
        m[header.blocks[victim].offset as usize] ^= 0xff;
        let r = ShardReader::open(Cursor::new(m)).unwrap();
        assert!(r.open_block(victim).is_err(), "victim {victim} must fail");
        for k in (0..header.nblocks()).filter(|&k| k != victim) {
            let lazy = r.lazy_block::<f64>(k).unwrap();
            let n = lazy.nclasses();
            let got = lazy.retrieve(n).unwrap();
            let lazy = clean.lazy_block::<f64>(k).unwrap();
            let want = lazy.retrieve(n).unwrap();
            assert_eq!(got.data(), want.data(), "victim {victim}, block {k}");
        }
    }
}
