//! Deterministic corrupt/truncated-input fuzzing of the MGRS shard
//! index parser, in the style of `tests/fuzz_decoders.rs`. The contract
//! under test: a malformed shard yields a typed `Err` — it must
//! **never** panic, abort on a huge allocation, or read out of bounds —
//! and a corrupt *block* must not poison retrieval of any other block.

use std::io::Cursor;

use mgr::compress::Codec;
use mgr::grid::Tensor;
use mgr::storage::shard::{shard_var_len, SHARD_FIXED_LEN};
use mgr::storage::{ShardHeader, ShardReader, ShardWriter};
use mgr::util::rng::Rng;

fn sample_shard(codec: Codec, blocks: usize) -> (Vec<u8>, ShardHeader) {
    let field = Tensor::<f64>::from_fn(&[17, 9], |idx| {
        ((idx[0] as f64) * 0.37).sin() + ((idx[1] as f64) * 0.21).cos()
    });
    let w = ShardWriter::<f64>::new(codec, 2);
    w.write(&field, 0, blocks, 1e-3).unwrap()
}

/// Open + exhaustively exercise a (possibly corrupt) shard buffer: the
/// index parse, every block open, and every retrieval prefix. Nothing
/// here may panic; errors are fine.
fn exercise(buf: &[u8]) {
    let _ = ShardHeader::parse(buf);
    let _ = shard_var_len(buf);
    if let Ok(r) = ShardReader::open(Cursor::new(buf.to_vec())) {
        for k in 0..r.nblocks() {
            if let Ok(lazy) = r.lazy_block::<f64>(k) {
                for keep in 1..=lazy.nclasses() {
                    let _ = lazy.retrieve(keep);
                }
            }
            // the wrong-dtype path must also stay total
            let _ = r.lazy_block::<f32>(k).is_err();
        }
    }
}

#[test]
fn truncation_sweep_over_every_prefix_length() {
    for codec in [Codec::Zlib, Codec::HuffRle] {
        let (bytes, _) = sample_shard(codec, 2);
        // a shard truncated anywhere — mid-prelude, mid-table, mid-block
        // — is rejected at open (the index pins the exact payload size)
        for len in 0..bytes.len() {
            assert!(
                ShardReader::open(Cursor::new(bytes[..len].to_vec())).is_err(),
                "{codec:?} truncated to {len} bytes must be rejected"
            );
            assert!(ShardHeader::parse(&bytes[..len]).is_err(), "{codec:?} len {len}");
        }
        exercise(&bytes); // the intact shard must fully retrieve
    }
}

#[test]
fn bit_flips_across_the_index_never_panic() {
    let (bytes, header) = sample_shard(Codec::Zlib, 2);
    // every bit of the index region, plus a tail of payload bytes
    let probe = header.header_bytes() + 64.min(bytes.len() - header.header_bytes());
    for i in 0..probe {
        for bit in 0..8 {
            let mut m = bytes.clone();
            m[i] ^= 1 << bit;
            exercise(&m);
        }
    }
}

#[test]
fn random_mutations_never_panic() {
    let (bytes, _) = sample_shard(Codec::HuffRle, 4);
    let mut rng = Rng::new(42);
    for _ in 0..500 {
        let mut m = bytes.clone();
        match rng.below(3) {
            0 => {
                let i = rng.below(m.len());
                m[i] ^= 1 << rng.below(8);
            }
            1 => {
                let i = rng.below(m.len());
                m[i] = rng.below(256) as u8;
            }
            _ => {
                let i = rng.below(m.len());
                let l = 1 + rng.below(16).min(m.len() - i - 1);
                m.drain(i..i + l);
            }
        }
        exercise(&m);
    }
}

#[test]
fn foreign_magic_and_garbage_rejected() {
    let mut rng = Rng::new(7);
    for len in [0usize, 1, 4, SHARD_FIXED_LEN, 64, 200, 1000] {
        let garbage: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        assert!(ShardReader::open(Cursor::new(garbage.clone())).is_err());
        assert!(ShardHeader::parse(&garbage).is_err());
    }
    // right magic, garbage tail
    let mut buf = b"MGRS".to_vec();
    buf.extend((0..200).map(|_| rng.below(256) as u8));
    assert!(ShardReader::open(Cursor::new(buf)).is_err());
    // a zip is not a shard
    assert!(ShardHeader::parse(b"PK\x03\x04 the rest of a zip file").is_err());
}

#[test]
fn offset_tables_pointing_past_eof_are_rejected() {
    let (bytes, header) = sample_shard(Codec::Zlib, 2);
    let table = SHARD_FIXED_LEN + 8 * header.shape.len();
    // per-block entry layout: start(0..8) len(8..16) offset(16..24) bytes(24..32)
    for k in 0..header.nblocks() {
        for field in [16usize, 24] {
            for huge in [u64::MAX, bytes.len() as u64 + 1, 1 << 40] {
                let mut m = bytes.clone();
                let pos = table + 32 * k + field;
                m[pos..pos + 8].copy_from_slice(&huge.to_le_bytes());
                assert!(
                    ShardHeader::parse(&m).is_err() || ShardReader::open(Cursor::new(m.clone())).is_err(),
                    "block {k} field +{field} = {huge} must not open"
                );
                exercise(&m);
            }
        }
    }
}

#[test]
fn corrupt_block_is_isolated_from_the_others() {
    let (bytes, header) = sample_shard(Codec::Zlib, 4);
    let clean = ShardReader::open(Cursor::new(bytes.clone())).unwrap();
    for victim in 0..header.nblocks() {
        // clobber the victim's MGRC magic: the index still parses, the
        // victim fails at its own open, everyone else is bit-identical
        let mut m = bytes.clone();
        m[header.blocks[victim].offset as usize] ^= 0xff;
        let r = ShardReader::open(Cursor::new(m)).unwrap();
        assert!(r.open_block(victim).is_err(), "victim {victim} must fail");
        for k in (0..header.nblocks()).filter(|&k| k != victim) {
            let lazy = r.lazy_block::<f64>(k).unwrap();
            let n = lazy.nclasses();
            let got = lazy.retrieve(n).unwrap();
            let lazy = clean.lazy_block::<f64>(k).unwrap();
            let want = lazy.retrieve(n).unwrap();
            assert_eq!(got.data(), want.data(), "victim {victim}, block {k}");
        }
    }
}
