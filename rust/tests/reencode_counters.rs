//! Proof that `mgr reencode` takes the structurally-cheap paths: pure
//! fidelity truncation performs **zero** entropy decodes and **zero**
//! dequantizations, and a codec conversion re-runs the entropy stage
//! only (it never dequantizes).
//!
//! The evidence is the process-wide monotonic call counters
//! [`decode_stream_count`] / [`dequantize_count`]. Because the counters
//! are process-wide and `cargo test` runs a binary's `#[test]`s on
//! parallel threads, this file deliberately holds exactly ONE test —
//! integration-test binaries are separate processes, so nothing else
//! can increment the counters between the snapshots below.
//!
//! [`decode_stream_count`]: mgr::compress::pipeline::decode_stream_count
//! [`dequantize_count`]: mgr::compress::quantize::dequantize_count

use mgr::api::reencode::{reencode, ReencodeSpec};
use mgr::api::Fidelity;
use mgr::compress::pipeline::decode_stream_count;
use mgr::compress::quantize::dequantize_count;
use mgr::compress::Codec;
use mgr::grid::{Hierarchy, Tensor};
use mgr::storage::{ProgressiveWriter, ShardWriter};

#[test]
fn truncation_decodes_nothing_and_recode_never_dequantizes() {
    // build the artifacts BEFORE snapshotting: writing measures the
    // per-class annotations by decoding, which is expected to count
    let t = Tensor::<f64>::from_fn(&[17, 9], |idx| {
        ((idx[0] as f64) * 0.37).sin() + ((idx[1] as f64) * 0.21).cos()
    });
    let h = Hierarchy::uniform(t.shape());
    let mut w = ProgressiveWriter::<f64>::new(h, Codec::Zlib);
    let (container, _) = w.write(&t, 1e-3).unwrap();
    let (shard, _) = ShardWriter::<f64>::new(Codec::Zlib, 1)
        .write_grid(&t, &[2, 2], 1e-3)
        .unwrap();

    // pure truncation — a container and a whole shard: zero entropy
    // decodes, zero dequantizations, on top of the reports agreeing
    let spec = ReencodeSpec {
        fidelity: Fidelity::Classes(2),
        ..Default::default()
    };
    let d0 = decode_stream_count();
    let q0 = dequantize_count();
    let (_, r1) = reencode(&container, &spec).unwrap();
    let (_, r2) = reencode(&shard, &spec).unwrap();
    assert_eq!(
        decode_stream_count() - d0,
        0,
        "truncation must not entropy-decode"
    );
    assert_eq!(dequantize_count() - q0, 0, "truncation must not dequantize");
    assert_eq!(r1.bytes_decoded, 0);
    assert_eq!(r2.bytes_decoded, 0);
    assert_eq!(r1.blocks_copied, 1);
    assert_eq!(r2.blocks_copied, 4);

    // codec conversion: the entropy stage runs (once per kept class),
    // dequantization still never does
    let spec = ReencodeSpec {
        codec: Some(Codec::HuffRle),
        ..Default::default()
    };
    let d0 = decode_stream_count();
    let q0 = dequantize_count();
    let (_, r3) = reencode(&container, &spec).unwrap();
    assert!(
        decode_stream_count() > d0,
        "codec conversion re-runs the entropy stage"
    );
    assert_eq!(
        dequantize_count() - q0,
        0,
        "codec conversion must not dequantize"
    );
    assert!(r3.bytes_decoded > 0);
}
