//! Property-style round-trip matrix over the `mgr::api` facade:
//! dims 1D/2D/3D × f32/f64 × every codec × every `Fidelity` variant,
//! asserting that retrieved error never exceeds the requested bound and
//! that byte budgets resolve to the longest fitting class prefix.

use mgr::api::{AnyTensor, Codec, Dtype, Fidelity, Session};
use mgr::grid::Tensor;

/// Smooth deterministic field with O(1) values on any shape.
fn field(shape: &[usize], dtype: Dtype) -> AnyTensor {
    let f64_field: AnyTensor = Tensor::<f64>::from_fn(shape, |idx| {
        idx.iter()
            .enumerate()
            .map(|(d, &i)| ((d as f64 + 1.3) * i as f64 * 0.21).sin())
            .product::<f64>()
            + 0.25
    })
    .into();
    f64_field.cast(dtype)
}

/// Measured-annotation slack: errors are recorded in the container's
/// scalar type while the test compares in widened f64 space, so allow a
/// relative half-ulp-of-f32 margin.
fn within(err: f64, bound: f64) -> bool {
    err <= bound * (1.0 + 1e-6) + 1e-12
}

#[test]
fn roundtrip_matrix_honors_every_fidelity_request() {
    let shapes: [&[usize]; 3] = [&[33], &[17, 17], &[9, 9, 9]];
    for shape in shapes {
        for dtype in [Dtype::F32, Dtype::F64] {
            // f32 quantization can't honor bounds below its precision at
            // O(1) values, so the bound scales with the dtype
            let eb = match dtype {
                Dtype::F32 => 1e-2,
                Dtype::F64 => 1e-4,
            };
            for codec in Codec::ALL {
                let label = format!("{shape:?} {dtype} {}", codec.name());
                let session = Session::builder()
                    .shape(shape)
                    .dtype(dtype)
                    .codec(codec)
                    .error_bound(eb)
                    .build()
                    .unwrap();
                let data = field(shape, dtype);
                let refactored = session.refactor(&data).unwrap();
                assert_eq!(refactored.dtype(), dtype, "{label}");
                assert_eq!(refactored.shape(), shape, "{label}");
                let header = refactored.header().clone();
                let nclasses = refactored.nclasses();

                // Fidelity::All — the full reconstruction meets the
                // session's error bound
                let full = session.retrieve(&refactored, Fidelity::All).unwrap();
                assert_eq!(full.dtype(), dtype, "{label}");
                let full_err = full.linf_to(&data).unwrap();
                assert!(within(full_err, eb), "{label}: full err {full_err} > eb {eb}");

                // Fidelity::Classes(k) — error matches the measured
                // annotation and is non-increasing in k
                let mut last = f64::INFINITY;
                for keep in 1..=nclasses {
                    let approx = session.retrieve(&refactored, Fidelity::Classes(keep)).unwrap();
                    let err = approx.linf_to(&data).unwrap();
                    let recorded = header.segments[keep - 1].linf;
                    assert!(
                        within(err, recorded),
                        "{label} keep={keep}: err {err} > recorded {recorded}"
                    );
                    assert!(
                        err <= last * (1.0 + 1e-6) + 1e-12,
                        "{label} keep={keep}: error increased {last} -> {err}"
                    );
                    last = err;
                }

                // Fidelity::ErrorBound(target) — retrieved error meets
                // every satisfiable target
                for factor in [2.0, 10.0, 100.0] {
                    let target = eb * factor;
                    let fid = Fidelity::ErrorBound(target);
                    let approx = session.retrieve(&refactored, fid).unwrap();
                    let err = approx.linf_to(&data).unwrap();
                    assert!(
                        within(err, target),
                        "{label} target={target}: err {err} exceeds the requested bound"
                    );
                }

                // Fidelity::ByteBudget(b) — the longest class prefix whose
                // container-recorded size fits b, for every prefix boundary
                for keep in 1..=nclasses {
                    let budget = header.prefix_bytes(keep);
                    assert_eq!(
                        refactored.resolve(Fidelity::ByteBudget(budget)).unwrap(),
                        keep,
                        "{label} budget={budget}"
                    );
                    let got = session.retrieve(&refactored, Fidelity::ByteBudget(budget)).unwrap();
                    let want = session.retrieve(&refactored, Fidelity::Classes(keep)).unwrap();
                    assert_eq!(got, want, "{label} budget={budget}");
                }
                // over-generous budgets keep everything; an impossible
                // budget is an error, not a silent coarsest-class fallback
                let all = refactored.resolve(Fidelity::ByteBudget(u64::MAX)).unwrap();
                assert_eq!(all, nclasses, "{label}");
                let tiny = header.segments[0].bytes - 1;
                assert!(
                    session.retrieve(&refactored, Fidelity::ByteBudget(tiny)).is_err(),
                    "{label}: sub-coarsest budget must be rejected"
                );

                // out-of-range class prefixes are rejected
                assert!(session.retrieve(&refactored, Fidelity::Classes(0)).is_err());
                let over = Fidelity::Classes(nclasses + 1);
                assert!(session.retrieve(&refactored, over).is_err());
            }
        }
    }
}

#[test]
fn store_then_reload_preserves_every_fidelity() {
    let shape = [17usize, 17];
    let session = Session::builder()
        .shape(&shape)
        .codec(Codec::HuffRle)
        .error_bound(1e-3)
        .build()
        .unwrap();
    let data = field(&shape, Dtype::F64);
    let refactored = session.refactor(&data).unwrap();

    let path = std::env::temp_dir().join("mgr_api_matrix_roundtrip.mgr");
    session.store_file(&refactored, &path).unwrap();
    let reloaded = mgr::api::Refactored::from_file(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(reloaded.as_bytes(), refactored.as_bytes());

    // a consumer session rebuilt from the container alone retrieves
    // identically at every class prefix
    let consumer = Session::builder().for_container(&reloaded).build().unwrap();
    for keep in 1..=reloaded.nclasses() {
        assert_eq!(
            consumer.retrieve(&reloaded, Fidelity::Classes(keep)).unwrap(),
            session.retrieve(&refactored, Fidelity::Classes(keep)).unwrap(),
            "keep={keep}"
        );
    }
}

#[test]
fn batch_refactor_matches_serial_across_dtypes() {
    for dtype in [Dtype::F32, Dtype::F64] {
        let shape = [9usize, 9];
        let session = Session::builder()
            .shape(&shape)
            .dtype(dtype)
            .error_bound(1e-2)
            .workers(3)
            .build()
            .unwrap();
        let fields: Vec<AnyTensor> = (0..6)
            .map(|i| {
                let f64_field: AnyTensor = Tensor::<f64>::from_fn(&shape, |idx| {
                    ((idx[0] * 9 + idx[1]) as f64 * 0.13 + i as f64 * 0.7).cos()
                })
                .into();
                f64_field.cast(dtype)
            })
            .collect();
        let batch = session.refactor_batch(fields.clone());
        assert_eq!(batch.len(), fields.len());
        for (f, got) in fields.iter().zip(batch) {
            let got = got.unwrap();
            let want = session.refactor(f).unwrap();
            assert_eq!(got.as_bytes(), want.as_bytes(), "{dtype}");
        }
    }
}
