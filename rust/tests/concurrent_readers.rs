//! The concurrency battery: every retrieval front door shared across
//! threads behind one `Arc`, with every result asserted **bitwise**
//! against a serial baseline computed up front.
//!
//! What this file pins down (the PR's tentpole contract):
//!
//! * `Refactored`, `OpenContainer`, `Retrieved`, `Sharded`, and
//!   `Session` are `Send + Sync` — enforced at compile time below.
//! * N threads retrieving / upgrading / region-reading through one
//!   shared reader get results identical to the single-threaded path,
//!   even with `drop_cache` calls racing them.
//! * A byte-budgeted decoded-class cache never exceeds its budget, no
//!   matter how many threads contend, and never changes results.
//!
//! Long-loop variants of the hottest races are `#[ignore]`d; CI runs
//! them in a dedicated stress job (`cargo test -q -- --ignored`).

use std::ops::Range;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;

use mgr::api::{AnyTensor, Fidelity, OpenContainer, Refactored, Retrieved, Session, Sharded};
use mgr::grid::Tensor;

fn assert_sync<T: Send + Sync>() {}

#[test]
fn every_front_door_is_send_and_sync() {
    assert_sync::<Refactored>();
    assert_sync::<OpenContainer>();
    assert_sync::<Retrieved>();
    assert_sync::<Sharded>();
    assert_sync::<Session>();
    assert_sync::<mgr::serve::Server>();
}

fn smooth(shape: &[usize]) -> AnyTensor {
    Tensor::<f64>::from_fn(shape, |idx| {
        idx.iter()
            .enumerate()
            .map(|(d, &i)| ((d + 1) as f64 * i as f64 * 0.19).sin())
            .sum()
    })
    .into()
}

fn refactored(shape: &[usize]) -> Refactored {
    let s = Session::builder().shape(shape).build().unwrap();
    s.refactor(&smooth(shape)).unwrap()
}

/// Serial baseline: one tensor per class prefix, computed before any
/// concurrency starts (on a fresh reader so the cache plays no part).
fn baseline(r: &Refactored) -> Vec<AnyTensor> {
    (1..=r.nclasses())
        .map(|k| r.retrieve(Fidelity::Classes(k)).unwrap())
        .collect()
}

fn hammer_refactored(r: &Refactored, threads: usize, rounds: usize) {
    let want = baseline(r);
    let nclasses = r.nclasses();
    thread::scope(|scope| {
        for t in 0..threads {
            let want = &want;
            scope.spawn(move || {
                for i in 0..rounds {
                    let k = 1 + (t * 7 + i) % nclasses;
                    let got = r.retrieve(Fidelity::Classes(k)).unwrap();
                    assert_eq!(&got, &want[k - 1], "thread {t}, round {i}, keep {k}");
                    // every fourth round, race an eviction against the
                    // other threads' in-flight retrievals
                    if i % 4 == 3 {
                        r.drop_cache();
                    }
                }
            });
        }
    });
}

#[test]
fn eight_threads_share_one_refactored_bitwise() {
    let r = refactored(&[17, 17]);
    hammer_refactored(&r, 8, 12);
}

#[test]
#[ignore = "long-loop stress variant; CI runs it in the dedicated --ignored job"]
fn stress_refactored_sharing() {
    let r = refactored(&[33, 33]);
    hammer_refactored(&r, 16, 200);
}

#[test]
fn upgrades_race_bitwise_through_one_open_container() {
    let r = refactored(&[17, 17]);
    let oc = Arc::new(r.open().unwrap());
    let want = baseline(&r);
    let nclasses = r.nclasses();
    thread::scope(|scope| {
        for t in 0..8 {
            let oc = Arc::clone(&oc);
            let want = &want;
            scope.spawn(move || {
                for i in 0..8 {
                    let k0 = 1 + (t + i) % nclasses;
                    let k1 = 1 + (t * 3 + i) % nclasses;
                    let coarse = oc.retrieve(Fidelity::Classes(k0)).unwrap();
                    assert_eq!(coarse.tensor(), &want[k0 - 1]);
                    // upgrades (and downgrades) resolve against the same
                    // shared cache the other threads are filling
                    let next = coarse.upgrade(Fidelity::Classes(k1)).unwrap();
                    assert_eq!(next.tensor(), &want[k1 - 1]);
                }
            });
        }
    });
    // with every class decoded, the source has been read exactly once
    assert_eq!(oc.bytes_read(), oc.total_bytes());
}

#[test]
fn shard_threads_mix_full_region_and_eviction_bitwise() {
    let s = Session::builder().shape(&[17, 9]).build().unwrap();
    let data = smooth(&[17, 9]);
    let sharded = Arc::new(s.refactor_sharded(&data, 4).unwrap());
    let rois: Vec<Vec<Range<usize>>> =
        vec![vec![0..5, 0..9], vec![3..12, 2..7], vec![8..17, 0..4], vec![0..17, 0..9]];
    let want_full = sharded.retrieve(Fidelity::All).unwrap();
    let want_coarse = sharded.retrieve(Fidelity::Classes(1)).unwrap();
    let want_regions: Vec<AnyTensor> = rois
        .iter()
        .map(|roi| sharded.retrieve_region(roi, Fidelity::All).unwrap())
        .collect();
    thread::scope(|scope| {
        for t in 0..8 {
            let sharded = Arc::clone(&sharded);
            let rois = &rois;
            let want_full = &want_full;
            let want_coarse = &want_coarse;
            let want_regions = &want_regions;
            scope.spawn(move || {
                for i in 0..6 {
                    match (t + i) % 4 {
                        0 => {
                            assert_eq!(&sharded.retrieve(Fidelity::All).unwrap(), want_full);
                        }
                        1 => {
                            assert_eq!(
                                &sharded.retrieve(Fidelity::Classes(1)).unwrap(),
                                want_coarse
                            );
                        }
                        2 => {
                            let j = (t * 5 + i) % rois.len();
                            let got = sharded.retrieve_region(&rois[j], Fidelity::All).unwrap();
                            assert_eq!(&got, &want_regions[j], "roi {j}");
                        }
                        _ => sharded.drop_cache(),
                    }
                }
            });
        }
    });
    // every result above was bit-identical; the shared counter is exact
    assert!(sharded.bytes_read() >= sharded.index_bytes());
}

#[test]
#[ignore = "long-loop stress variant; CI runs it in the dedicated --ignored job"]
fn stress_shard_sharing() {
    let s = Session::builder().shape(&[33, 17]).build().unwrap();
    let sharded = Arc::new(s.refactor_sharded(&smooth(&[33, 17]), 4).unwrap());
    let want = sharded.retrieve(Fidelity::All).unwrap();
    let roi: Vec<Range<usize>> = vec![5..29, 3..14];
    let want_roi = sharded.retrieve_region(&roi, Fidelity::All).unwrap();
    thread::scope(|scope| {
        for t in 0..12 {
            let sharded = Arc::clone(&sharded);
            let want = &want;
            let roi = &roi;
            let want_roi = &want_roi;
            scope.spawn(move || {
                for i in 0..60 {
                    match (t + i) % 3 {
                        0 => assert_eq!(&sharded.retrieve(Fidelity::All).unwrap(), want),
                        1 => assert_eq!(
                            &sharded.retrieve_region(roi, Fidelity::All).unwrap(),
                            want_roi
                        ),
                        _ => sharded.drop_cache(),
                    }
                }
            });
        }
    });
}

#[test]
fn cache_budget_is_never_exceeded_under_contention() {
    let r = refactored(&[33, 33]);
    // force real eviction traffic: budget ~ half of the fully decoded
    // footprint (every class of an n-element f64 field decodes to
    // roughly n values total across classes)
    let full_bytes: u64 = r
        .header()
        .segments
        .iter()
        .map(|s| s.nvalues * 8)
        .sum();
    let budget = (full_bytes / 2).max(64);
    r.set_cache_budget(Some(budget)).unwrap();
    let want = baseline(&r);
    let nclasses = r.nclasses();
    let stop = AtomicBool::new(false);
    thread::scope(|scope| {
        // a sampler thread observes the budget invariant *while* the
        // workers churn — not just at quiescence
        let sampler = scope.spawn(|| {
            let mut peak = 0;
            while !stop.load(Ordering::Acquire) {
                let stats = r.cache_stats();
                assert!(
                    stats.cached_bytes <= budget,
                    "cache {}B exceeded budget {budget}B",
                    stats.cached_bytes
                );
                peak = peak.max(stats.cached_bytes);
                thread::yield_now();
            }
            peak
        });
        let workers: Vec<_> = (0..8)
            .map(|t| {
                let want = &want;
                scope.spawn(move || {
                    // forward and reverse sweeps maximize eviction churn
                    for i in 0..10 {
                        let k = if t % 2 == 0 {
                            1 + (t + i) % nclasses
                        } else {
                            nclasses - (t + i) % nclasses
                        };
                        let got = r.retrieve(Fidelity::Classes(k)).unwrap();
                        assert_eq!(&got, &want[k - 1]);
                    }
                })
            })
            .collect();
        for w in workers {
            w.join().unwrap();
        }
        stop.store(true, Ordering::Release);
        sampler.join().unwrap();
    });
    let stats = r.cache_stats();
    assert!(stats.cached_bytes <= budget);
    assert!(stats.evictions > 0, "the budget must have actually bitten");
    assert_eq!(stats.budget, Some(budget));
    // lifting the budget restores unbounded caching, results unchanged
    r.set_cache_budget(None).unwrap();
    assert_eq!(&r.retrieve(Fidelity::All).unwrap(), want.last().unwrap());
}

#[test]
#[ignore = "long-loop stress variant; CI runs it in the dedicated --ignored job"]
fn stress_cache_budget_contention() {
    let r = refactored(&[33, 33]);
    let full_bytes: u64 = r.header().segments.iter().map(|s| s.nvalues * 8).sum();
    let budget = (full_bytes / 3).max(64);
    r.set_cache_budget(Some(budget)).unwrap();
    let want = baseline(&r);
    let nclasses = r.nclasses();
    thread::scope(|scope| {
        for t in 0..16 {
            let want = &want;
            scope.spawn(move || {
                for i in 0..150 {
                    let k = 1 + (t * 11 + i * 3) % nclasses;
                    assert_eq!(&r.retrieve(Fidelity::Classes(k)).unwrap(), &want[k - 1]);
                    let stats = r.cache_stats();
                    assert!(stats.cached_bytes <= budget);
                    if i % 17 == 0 {
                        r.drop_cache();
                    }
                }
            });
        }
    });
}

#[test]
fn session_read_verbs_never_wait_on_create_verbs() {
    // the coarse-lock regression at the battery level: read-only verbs
    // (retrieve, plan, stats) proceed while create verbs hold the
    // machinery, across more threads than the in-module regression
    let s = Session::builder().shape(&[17, 17]).build().unwrap();
    let data = smooth(&[17, 17]);
    let r = s.refactor(&data).unwrap();
    let want = r.retrieve(Fidelity::All).unwrap();
    thread::scope(|scope| {
        let writers: Vec<_> = (0..2)
            .map(|_| {
                scope.spawn(|| {
                    for _ in 0..6 {
                        s.refactor(&data).unwrap();
                    }
                })
            })
            .collect();
        let readers: Vec<_> = (0..6)
            .map(|_| {
                scope.spawn(|| {
                    for _ in 0..6 {
                        assert_eq!(s.retrieve(&r, Fidelity::All).unwrap(), want);
                        s.plan(&r).unwrap();
                        s.stats();
                    }
                })
            })
            .collect();
        for h in writers.into_iter().chain(readers) {
            h.join().unwrap();
        }
    });
}

#[test]
fn clones_and_arcs_share_one_cache_lineage() {
    // an Arc<Refactored> and plain clones are the same sharing story:
    // one decode per class per lineage, bit-identical everywhere
    let r = Arc::new(refactored(&[17, 17]));
    let want = baseline(&r);
    let nclasses = r.nclasses();
    thread::scope(|scope| {
        for t in 0..8 {
            let r = if t % 2 == 0 {
                Arc::clone(&r)
            } else {
                Arc::new((*r).clone()) // a clone still shares bytes + cache
            };
            let want = &want;
            scope.spawn(move || {
                for i in 0..6 {
                    let k = 1 + (t + i) % nclasses;
                    assert_eq!(&r.retrieve(Fidelity::Classes(k)).unwrap(), &want[k - 1]);
                }
            });
        }
    });
    let stats = r.cache_stats();
    // sharing means the cache saw far fewer misses than retrievals
    assert!(stats.hits > 0, "{stats:?}");
    assert_eq!(stats.misses as usize, nclasses, "one decode per class");
}
