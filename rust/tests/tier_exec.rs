//! Acceptance battery for tiered-storage **execution** (`storage::exec`):
//! the bytes a `Placement` plans must actually move, retrieval through
//! the tier ladder must be bit-identical to direct container retrieval
//! for every dtype × codec, the prefetcher must cut upgrade latency
//! without changing results, over-capacity placements must be refused
//! with a typed error and no partial moves, and the mover's *modeled*
//! retrieval ordering must agree with the executor's *measured* one.

use std::collections::HashSet;
use std::io::Read;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use mgr::api::{AnyTensor, Dtype, Error, Fidelity, OpenContainer, Refactored, Session};
use mgr::compress::Codec;
use mgr::grid::Tensor;
use mgr::storage::exec::{
    class_sizes, ExecError, TierExecutor, TierManifest, TierReadOptions, TierRoot, TieredReader,
    Throttle,
};
use mgr::storage::{place_classes, StorageTier, TierSpec};

fn tmp_base(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "mgr_tier_exec_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn field_for(dtype: Dtype, n: usize) -> AnyTensor {
    match dtype {
        Dtype::F32 => Tensor::<f32>::from_fn(&[n, n], |idx| {
            (idx[0] as f32 * 0.31).sin() + (idx[1] as f32 * 0.17).cos()
        })
        .into(),
        Dtype::F64 => Tensor::<f64>::from_fn(&[n, n], |idx| {
            (idx[0] as f64 * 0.31).sin() + (idx[1] as f64 * 0.17).cos()
        })
        .into(),
    }
}

fn three_roots(base: &Path) -> Vec<TierRoot> {
    vec![
        TierRoot::new(StorageTier::BurstBuffer, base.join("bb")),
        TierRoot::new(StorageTier::ParallelFs, base.join("pfs")),
        TierRoot::new(StorageTier::Archive, base.join("ar")),
    ]
}

/// Capacity-limit the fast tiers so the greedy placement spreads the
/// classes across all three: class 0 exactly fills the burst buffer,
/// the middle classes exactly fill the parallel fs, and the finest
/// class overflows to the archive.
fn spread_specs(sizes: &[u64]) -> Vec<TierSpec> {
    assert!(sizes.len() >= 3, "need at least three classes to spread");
    let middle: u64 = sizes[1..sizes.len() - 1].iter().sum();
    vec![
        TierSpec {
            capacity: sizes[0],
            ..TierSpec::burst_buffer()
        },
        TierSpec {
            capacity: middle,
            ..TierSpec::parallel_fs()
        },
        TierSpec::archive(),
    ]
}

fn refactor_to_file(
    base: &Path,
    dtype: Dtype,
    codec: Codec,
    n: usize,
) -> (Session, Refactored, PathBuf) {
    let session = Session::builder()
        .shape(&[n, n])
        .dtype(dtype)
        .codec(codec)
        .build()
        .unwrap();
    let r = session.refactor(&field_for(dtype, n)).unwrap();
    let path = base.join("f.mgr");
    session.store_file(&r, &path).unwrap();
    (session, r, path)
}

#[test]
fn executed_bytes_match_the_plan_per_tier_exactly() {
    let base = tmp_base("bytes");
    let (_session, _r, path) = refactor_to_file(&base, Dtype::F64, Codec::Zlib, 33);
    let sizes = class_sizes(&path).unwrap();
    let specs = spread_specs(&sizes);
    let placement = place_classes(&sizes, &specs);
    assert!(placement.over_capacity.is_empty());
    let used: HashSet<StorageTier> = placement.assignment.iter().copied().collect();
    assert_eq!(used.len(), 3, "plan must spread: {:?}", placement.assignment);

    let exec = TierExecutor::new(three_roots(&base)).unwrap();
    let manifest = exec.execute(&placement, &path).unwrap();

    // the measured per-tier write counters equal the plan EXACTLY
    let stats = exec.stats();
    for tier in [
        StorageTier::BurstBuffer,
        StorageTier::ParallelFs,
        StorageTier::Archive,
    ] {
        let planned: u64 = placement
            .assignment
            .iter()
            .zip(&placement.bytes)
            .filter(|(t, _)| **t == tier)
            .map(|(_, b)| *b)
            .sum();
        assert_eq!(stats.tier(tier).bytes_written, planned, "{tier:?}");
    }
    // ... and so do the segment files on disk
    for c in &manifest.classes {
        let on_disk = std::fs::metadata(&c.file).unwrap().len();
        assert_eq!(on_disk, c.bytes, "class {}", c.class);
        assert_eq!(c.bytes, placement.bytes[c.class]);
    }
    let meta_on_disk = std::fs::metadata(&manifest.meta_file).unwrap().len();
    assert_eq!(meta_on_disk, manifest.meta_bytes);
    assert_eq!(stats.meta_bytes, manifest.meta_bytes);
    assert!(TierManifest::path_for(&path).exists());
    std::fs::remove_dir_all(&base).ok();
}

#[test]
fn tier_ladder_retrieval_is_bit_identical_for_every_dtype_and_codec() {
    for dtype in [Dtype::F32, Dtype::F64] {
        for codec in [Codec::Zlib, Codec::HuffRle] {
            let base = tmp_base(&format!("ladder_{dtype:?}_{}", codec.name()));
            let (session, r, path) = refactor_to_file(&base, dtype, codec, 33);
            let sizes = class_sizes(&path).unwrap();
            let placement = place_classes(&sizes, &spread_specs(&sizes));
            let exec = TierExecutor::new(three_roots(&base)).unwrap();
            exec.execute(&placement, &path).unwrap();

            let reader = TieredReader::open(TierManifest::path_for(&path)).unwrap();
            let tiered = OpenContainer::open(reader.source()).unwrap();
            let direct = OpenContainer::open_file(&path).unwrap();
            for keep in 1..=r.nclasses() {
                let a = tiered.retrieve(Fidelity::Classes(keep)).unwrap();
                let b = direct.retrieve(Fidelity::Classes(keep)).unwrap();
                assert_eq!(
                    a.tensor(),
                    b.tensor(),
                    "dtype {dtype:?} codec {} keep {keep}",
                    codec.name()
                );
            }
            // the in-memory session path agrees too
            let full = tiered.retrieve(Fidelity::All).unwrap();
            assert_eq!(full.tensor(), &session.retrieve(&r, Fidelity::All).unwrap());
            std::fs::remove_dir_all(&base).ok();
        }
    }
}

#[test]
fn prefetcher_cuts_upgrade_latency_without_changing_results() {
    let base = tmp_base("prefetch");
    let (_session, _r, path) = refactor_to_file(&base, Dtype::F64, Codec::Zlib, 33);
    let sizes = class_sizes(&path).unwrap();
    // class 0 on the (unthrottled) burst buffer, everything else on the
    // archive, whose reads we throttle hard
    let specs = vec![
        TierSpec {
            capacity: sizes[0],
            ..TierSpec::burst_buffer()
        },
        TierSpec::archive(),
    ];
    let placement = place_classes(&sizes, &specs);
    assert!(placement.over_capacity.is_empty());
    let roots = vec![
        TierRoot::new(StorageTier::BurstBuffer, base.join("bb")),
        TierRoot::new(StorageTier::Archive, base.join("ar")),
    ];
    let exec = TierExecutor::new(roots).unwrap();
    exec.execute(&placement, &path).unwrap();

    let slow = Throttle {
        read_bw: f64::INFINITY,
        write_bw: f64::INFINITY,
        latency: 0.08,
    };
    let manifest_path = TierManifest::path_for(&path);
    let opts = |prefetch: bool| TierReadOptions {
        prefetch,
        throttles: vec![(StorageTier::Archive, slow)],
    };

    // cold: no prefetcher — the upgrade pays the archive latency
    let plain = TieredReader::open_with(&manifest_path, opts(false)).unwrap();
    let plain_c = OpenContainer::open(plain.source()).unwrap();
    let coarse_plain = plain_c.retrieve(Fidelity::Classes(1)).unwrap();
    let t0 = Instant::now();
    let up_plain = coarse_plain.upgrade(Fidelity::Classes(2)).unwrap();
    let cold = t0.elapsed();

    // warm: touching class 0 schedules promotion of class 1; wait for
    // it (determinism hook), then the upgrade is served from memory
    let pre = TieredReader::open_with(&manifest_path, opts(true)).unwrap();
    let pre_c = OpenContainer::open(pre.source()).unwrap();
    let coarse_pre = pre_c.retrieve(Fidelity::Classes(1)).unwrap();
    assert!(
        pre.wait_promoted(1, Duration::from_secs(20)),
        "prefetcher never promoted class 1"
    );
    let t0 = Instant::now();
    let up_pre = coarse_pre.upgrade(Fidelity::Classes(2)).unwrap();
    let warm = t0.elapsed();

    // promotion never changes results
    let direct = OpenContainer::open_file(&path).unwrap();
    let want = direct.retrieve(Fidelity::Classes(2)).unwrap();
    assert_eq!(up_pre.tensor(), want.tensor());
    assert_eq!(up_plain.tensor(), want.tensor());
    assert_eq!(coarse_pre.tensor(), coarse_plain.tensor());

    // ... and it strictly reduces the measured upgrade latency: the
    // cold path sleeps >= the archive latency at least once, the warm
    // path never touches the archive
    let s = pre.stats();
    assert!(s.prefetch_hits > 0, "upgrade was not served from memory");
    assert!(s.prefetched_classes >= 1);
    assert!(
        warm < cold,
        "prefetched upgrade ({warm:?}) not faster than cold ({cold:?})"
    );
    assert!(warm.as_secs_f64() < slow.latency, "warm upgrade hit the archive");
    std::fs::remove_dir_all(&base).ok();
}

#[test]
fn over_capacity_is_a_typed_error_with_no_partial_moves() {
    let base = tmp_base("overcap");
    // a session whose only tier cannot hold anything
    let session = Session::builder()
        .shape(&[17, 17])
        .tiers(vec![TierSpec {
            capacity: 1,
            ..TierSpec::archive()
        }])
        .build()
        .unwrap();
    let r = session.refactor(&field_for(Dtype::F64, 17)).unwrap();
    let roots = three_roots(&base);
    let root_dirs: Vec<PathBuf> = roots.iter().map(|t| t.root.clone()).collect();
    let exec = TierExecutor::new(roots).unwrap();
    let path = base.join("f.mgr");

    let err = session.store_tiered(&r, &path, &exec).unwrap_err();
    match &err {
        Error::Tier(ExecError::OverCapacity(classes)) => {
            assert!(!classes.is_empty(), "over-capacity classes must be named")
        }
        other => panic!("expected Error::Tier(OverCapacity), got {other:?}"),
    }

    // the artifact was stored, but no segment byte moved and no
    // manifest was committed
    assert!(path.exists());
    for d in &root_dirs {
        assert_eq!(std::fs::read_dir(d).unwrap().count(), 0, "{}", d.display());
    }
    assert!(!TierManifest::path_for(&path).exists());
    let stats = exec.stats();
    assert!(stats.tiers.iter().all(|t| t.bytes_written == 0));
    assert_eq!(stats.meta_bytes, 0);
    std::fs::remove_dir_all(&base).ok();
}

#[test]
fn store_tiered_executes_and_roundtrips_through_the_facade() {
    let base = tmp_base("facade");
    let session = Session::builder().shape(&[17, 17]).build().unwrap();
    let r = session.refactor(&field_for(Dtype::F64, 17)).unwrap();
    let exec = TierExecutor::new(three_roots(&base)).unwrap();
    let path = base.join("f.mgr");
    let (placement, manifest) = session.store_tiered(&r, &path, &exec).unwrap();
    assert_eq!(placement.assignment.len(), r.nclasses());
    assert_eq!(manifest.nclasses, r.nclasses());

    let reader = TieredReader::open(TierManifest::path_for(&path)).unwrap();
    let round = OpenContainer::open(reader.source())
        .unwrap()
        .retrieve(Fidelity::All)
        .unwrap();
    assert_eq!(round.tensor(), &session.retrieve(&r, Fidelity::All).unwrap());
    std::fs::remove_dir_all(&base).ok();
}

#[test]
fn shard_artifacts_execute_and_reassemble_bitwise() {
    let base = tmp_base("shard");
    let session = Session::builder().shape(&[33, 33]).build().unwrap();
    let sharded = session.refactor_sharded(&field_for(Dtype::F64, 33), 2).unwrap();
    let path = base.join("f.mgrs");
    sharded.store_file(&path).unwrap();
    let original = std::fs::read(&path).unwrap();

    let sizes = class_sizes(&path).unwrap();
    assert!(sizes.iter().sum::<u64>() > 0);
    let placement = place_classes(&sizes, &spread_specs(&sizes));
    let exec = TierExecutor::new(three_roots(&base)).unwrap();
    let manifest = exec.execute(&placement, &path).unwrap();
    assert_eq!(manifest.total_bytes as usize, original.len());

    let reader = TieredReader::open(TierManifest::path_for(&path)).unwrap();
    let mut back = Vec::new();
    reader.source().read_to_end(&mut back).unwrap();
    assert_eq!(back, original, "tiered shard stream must be bit-identical");
    std::fs::remove_dir_all(&base).ok();
}

#[test]
fn modeled_retrieval_ordering_matches_measured_ordering() {
    let base = tmp_base("model");
    let (_session, _r, path) = refactor_to_file(&base, Dtype::F64, Codec::Zlib, 65);
    let sizes = class_sizes(&path).unwrap();
    let specs = spread_specs(&sizes);
    let placement = place_classes(&sizes, &specs);
    let exec = TierExecutor::new(three_roots(&base)).unwrap();
    exec.execute(&placement, &path).unwrap();
    let manifest_path = TierManifest::path_for(&path);

    // the MODEL: retrieval_time is monotone in fidelity, and full
    // fidelity costs strictly more than the coarsest class
    let nclasses = sizes.len();
    let modeled: Vec<f64> = (1..=nclasses)
        .map(|keep| placement.retrieval_time(&specs, keep).unwrap())
        .collect();
    for w in modeled.windows(2) {
        assert!(w[1] >= w[0] - 1e-12, "model must be monotone in fidelity");
    }
    assert!(modeled[nclasses - 1] > modeled[0]);

    // the MEASUREMENT: wall-clock seconds the executor's reader spent
    // in tier files for the same two fidelities (min of 5, fresh
    // reader each time so counters start at zero)
    let measure = |keep: usize| -> (f64, u64) {
        let mut best = f64::INFINITY;
        let mut bytes = 0u64;
        for _ in 0..5 {
            let reader = TieredReader::open(&manifest_path).unwrap();
            let c = OpenContainer::open(reader.source()).unwrap();
            c.retrieve(Fidelity::Classes(keep)).unwrap();
            let s = reader.stats();
            best = best.min(s.tiers.iter().map(|t| t.read_s).sum::<f64>());
            bytes = s.tiers.iter().map(|t| t.bytes_read).sum::<u64>();
        }
        (best, bytes)
    };
    let (lo_s, lo_b) = measure(1);
    let (hi_s, hi_b) = measure(nclasses);
    assert!(hi_b > lo_b, "full fidelity must read more bytes: {hi_b} vs {lo_b}");
    assert!(
        hi_s > lo_s,
        "measured ordering disagrees with the model: keep=1 took {lo_s:.6}s, \
         keep=all took {hi_s:.6}s"
    );
    std::fs::remove_dir_all(&base).ok();
}
