//! SIMD-vs-scalar bit-identity matrix.
//!
//! The stride-1 fast paths in `util::simd` promise *bit-identical* output
//! to their `_scalar` twins — that contract is what lets the refactor
//! kernels dispatch freely between paths without perturbing the lossless
//! round-trip or the quantizer's error bound. This suite sweeps the
//! contract across:
//!
//! * every row primitive × {f32, f64} × row lengths straddling the
//!   vector-width remainder cases (1..=65, both sides of 8/16/32/64);
//! * every whole kernel (GPK upsample, LPK mass-trans, IPK Thomas, the
//!   fused last-axis upsample-apply) × {f32, f64} × axes 0..3 × odd/even
//!   surrounding extents, against references built *only* from the
//!   `_scalar` twins;
//! * quantize/dequantize against plain serial loops.
//!
//! Comparisons use `to_f64().to_bits()` (f32→f64 widening is exact), so
//! any divergence — including signed-zero or rounding-mode drift — fails.

use mgr::compress::{dequantize, quantize, QuantMeta};
use mgr::refactor::axis::{self, axis_split};
use mgr::refactor::DimOps;
use mgr::util::rng::Rng;
use mgr::util::simd;
use mgr::util::Scalar;

/// Exact bit pattern of each element, widened to f64 (lossless for f32).
fn bits<T: Scalar>(v: &[T]) -> Vec<u64> {
    v.iter().map(|x| x.to_f64().to_bits()).collect()
}

fn randv<T: Scalar>(rng: &mut Rng, n: usize) -> Vec<T> {
    (0..n).map(|_| T::from_f64(rng.range(-1.0, 1.0))).collect()
}

/// Strictly increasing, non-uniform coordinates of length `m`.
fn coords(rng: &mut Rng, m: usize) -> Vec<f64> {
    let mut xs = Vec::with_capacity(m);
    let mut x = 0.0;
    for _ in 0..m {
        xs.push(x);
        x += rng.range(0.5, 1.5);
    }
    xs
}

/// Row lengths straddling every vector-width remainder boundary.
const LENS: [usize; 14] = [1, 2, 3, 4, 7, 8, 9, 15, 16, 17, 31, 33, 64, 65];

fn row_primitives_matrix<T: Scalar>(seed: u64) {
    let mut rng = Rng::new(seed);
    for &n in &LENS {
        let lo: Vec<T> = randv(&mut rng, n);
        let hi: Vec<T> = randv(&mut rng, n);
        let rv: Vec<T> = randv(&mut rng, n);
        let r = T::from_f64(0.37);
        let tag = format!("n={n} bytes={}", T::BYTES);

        let mut a = vec![T::ZERO; n];
        let mut b = vec![T::ZERO; n];
        simd::interp_row(&lo, &hi, r, &mut a);
        simd::interp_row_scalar(&lo, &hi, r, &mut b);
        assert_eq!(bits(&a), bits(&b), "interp_row {tag}");

        simd::interp_row_vr(&lo, &hi, &rv, &mut a);
        simd::interp_row_vr_scalar(&lo, &hi, &rv, &mut b);
        assert_eq!(bits(&a), bits(&b), "interp_row_vr {tag}");

        let odd0: Vec<T> = randv(&mut rng, n);
        let mut a = odd0.clone();
        let mut b = odd0.clone();
        simd::interp_sub_row(&lo, &hi, r, &mut a);
        simd::interp_sub_row_scalar(&lo, &hi, r, &mut b);
        assert_eq!(bits(&a), bits(&b), "interp_sub_row {tag}");

        let mut a = odd0.clone();
        let mut b = odd0.clone();
        simd::interp_add_row(&lo, &hi, r, &mut a);
        simd::interp_add_row_scalar(&lo, &hi, r, &mut b);
        assert_eq!(bits(&a), bits(&b), "interp_add_row {tag}");

        let taps: [T; 5] = [
            T::from_f64(0.1),
            T::from_f64(-0.4),
            T::from_f64(1.2),
            T::from_f64(-0.3),
            T::from_f64(0.05),
        ];
        let rows_v: Vec<Vec<T>> = (0..5).map(|_| randv(&mut rng, n)).collect();
        let rows: [&[T]; 5] = [&rows_v[0], &rows_v[1], &rows_v[2], &rows_v[3], &rows_v[4]];
        let mut a = vec![T::ZERO; n];
        let mut b = vec![T::ZERO; n];
        simd::five_tap_row(taps, rows, &mut a);
        simd::five_tap_row_scalar(taps, rows, &mut b);
        assert_eq!(bits(&a), bits(&b), "five_tap_row {tag}");

        let row0: Vec<T> = randv(&mut rng, n);
        let d = T::from_f64(0.8125);
        let mut a = row0.clone();
        let mut b = row0.clone();
        simd::scale_row(&mut a, d);
        simd::scale_row_scalar(&mut b, d);
        assert_eq!(bits(&a), bits(&b), "scale_row {tag}");

        let prev: Vec<T> = randv(&mut rng, n);
        let cur0: Vec<T> = randv(&mut rng, n);
        let s = T::from_f64(0.21);
        let mut a = cur0.clone();
        let mut b = cur0.clone();
        simd::sweep_fwd_row(&prev, &mut a, s, d);
        simd::sweep_fwd_row_scalar(&prev, &mut b, s, d);
        assert_eq!(bits(&a), bits(&b), "sweep_fwd_row {tag}");

        let next: Vec<T> = randv(&mut rng, n);
        let c = T::from_f64(-0.43);
        let mut a = cur0.clone();
        let mut b = cur0.clone();
        simd::sweep_bwd_row(&next, &mut a, c);
        simd::sweep_bwd_row_scalar(&next, &mut b, c);
        assert_eq!(bits(&a), bits(&b), "sweep_bwd_row {tag}");

        for sign in [T::ONE, T::from_f64(-1.0)] {
            let dst0: Vec<T> = randv(&mut rng, n);
            let src: Vec<T> = randv(&mut rng, n);
            let mut a = dst0.clone();
            let mut b = dst0.clone();
            simd::axpy_row(&mut a, &src, sign);
            simd::axpy_row_scalar(&mut b, &src, sign);
            assert_eq!(bits(&a), bits(&b), "axpy_row {tag}");
        }
    }
}

#[test]
fn row_primitives_bit_identical_f64() {
    row_primitives_matrix::<f64>(0x51_3D_01);
}

#[test]
fn row_primitives_bit_identical_f32() {
    row_primitives_matrix::<f32>(0x51_3D_02);
}

fn upsample_apply_row_matrix<T: Scalar>(seed: u64) {
    let mut rng = Rng::new(seed);
    for mc in [2usize, 3, 5, 9, 17, 33] {
        let a = mc - 1;
        let mf = 2 * a + 1;
        let s: Vec<T> = randv(&mut rng, mc);
        let r: Vec<T> = randv(&mut rng, a)
            .iter()
            .map(|v: &T| T::from_f64(0.5 + 0.4 * v.to_f64()))
            .collect();
        for sign in [T::ONE, T::from_f64(-1.0)] {
            let b0: Vec<T> = randv(&mut rng, mf);
            let mut dispatched = b0.clone();
            let mut scalar = b0.clone();
            let mut tmp = vec![T::ZERO; a];
            simd::upsample_apply_row(&s, &r, &mut dispatched, sign, &mut tmp);
            simd::upsample_apply_row_scalar(&s, &r, &mut scalar, sign);
            assert_eq!(
                bits(&dispatched),
                bits(&scalar),
                "upsample_apply_row mc={mc} bytes={}",
                T::BYTES
            );
        }
    }
}

#[test]
fn upsample_apply_row_bit_identical_f64() {
    upsample_apply_row_matrix::<f64>(0xAB_17_01);
}

#[test]
fn upsample_apply_row_bit_identical_f32() {
    upsample_apply_row_matrix::<f32>(0xAB_17_02);
}

// ---- whole-kernel matrix: references built only from `_scalar` twins ----

fn upsample_ref<T: Scalar>(src: &[T], src_shape: &[usize], ax: usize, r: &[T], dst: &mut [T]) {
    let (outer, mc, inner) = axis_split(src_shape, ax);
    let a = mc - 1;
    let mf = 2 * a + 1;
    for o in 0..outer {
        let sb = o * mc * inner;
        let db = o * mf * inner;
        for i in 0..a {
            let lo = &src[sb + i * inner..sb + (i + 1) * inner];
            let hi = &src[sb + (i + 1) * inner..sb + (i + 2) * inner];
            dst[db + 2 * i * inner..db + (2 * i + 1) * inner].copy_from_slice(lo);
            let odd = &mut dst[db + (2 * i + 1) * inner..db + (2 * i + 2) * inner];
            simd::interp_row_scalar(lo, hi, r[i], odd);
        }
        dst[db + 2 * a * inner..db + (2 * a + 1) * inner]
            .copy_from_slice(&src[sb + a * inner..sb + mc * inner]);
    }
}

fn masstrans_ref<T: Scalar>(
    src: &[T],
    src_shape: &[usize],
    ax: usize,
    ops: &DimOps<T>,
    dst: &mut [T],
) {
    let (outer, m, inner) = axis_split(src_shape, ax);
    let a = (m - 1) / 2;
    let k = &ops.k;
    for o in 0..outer {
        let sb = o * m * inner;
        let db = o * (a + 1) * inner;
        for i in 0..=a {
            let j = 2 * i;
            let t0 = if j >= 2 { k[0][i] } else { T::ZERO };
            let t1 = if j >= 1 { k[1][i] } else { T::ZERO };
            let t2 = k[2][i];
            let t3 = if j + 1 < m { k[3][i] } else { T::ZERO };
            let t4 = if j + 2 < m { k[4][i] } else { T::ZERO };
            let r0 = &src[sb + j.saturating_sub(2) * inner..][..inner];
            let r1 = &src[sb + j.saturating_sub(1) * inner..][..inner];
            let r2 = &src[sb + j * inner..][..inner];
            let r3 = &src[sb + (j + 1).min(m - 1) * inner..][..inner];
            let r4 = &src[sb + (j + 2).min(m - 1) * inner..][..inner];
            let row = &mut dst[db + i * inner..db + (i + 1) * inner];
            simd::five_tap_row_scalar([t0, t1, t2, t3, t4], [r0, r1, r2, r3, r4], row);
        }
    }
}

fn thomas_ref<T: Scalar>(buf: &mut [T], shape: &[usize], ax: usize, ops: &DimOps<T>) {
    let (outer, m, inner) = axis_split(shape, ax);
    for o in 0..outer {
        let b = o * m * inner;
        simd::scale_row_scalar(&mut buf[b..b + inner], ops.denom[0]);
        for i in 1..m {
            let (prev, cur) = buf[b + (i - 1) * inner..].split_at_mut(inner);
            let cur = &mut cur[..inner];
            simd::sweep_fwd_row_scalar(prev, cur, ops.sub[i], ops.denom[i]);
        }
        for i in (0..m - 1).rev() {
            let (cur, next) = buf[b + i * inner..].split_at_mut(inner);
            let cur = &mut cur[..inner];
            simd::sweep_bwd_row_scalar(&next[..inner], cur, ops.cp[i]);
        }
    }
}

fn upsample_apply_last_ref<T: Scalar>(
    src: &[T],
    src_shape: &[usize],
    r: &[T],
    buf: &mut [T],
    sign: T,
) {
    let d = src_shape.len();
    let mc = src_shape[d - 1];
    let mf = 2 * (mc - 1) + 1;
    let outer: usize = src_shape[..d - 1].iter().product();
    for o in 0..outer {
        let s = &src[o * mc..(o + 1) * mc];
        let b = &mut buf[o * mf..(o + 1) * mf];
        simd::upsample_apply_row_scalar(s, r, b, sign);
    }
}

fn kernel_matrix<T: Scalar>(seed: u64) {
    let mut rng = Rng::new(seed);
    for ax in 0..3usize {
        for mf in [5usize, 17] {
            for other in [4usize, 7] {
                let mc = (mf + 1) / 2;
                let xs = coords(&mut rng, mf);
                let ops: DimOps<T> = DimOps::new(&xs);
                let mut fshape = [other, other, other];
                fshape[ax] = mf;
                let mut cshape = fshape;
                cshape[ax] = mc;
                let flen: usize = fshape.iter().product();
                let clen: usize = cshape.iter().product();
                let tag = format!("axis={ax} mf={mf} other={other} bytes={}", T::BYTES);

                // GPK upsample: default dispatch and explicit workers
                let src: Vec<T> = randv(&mut rng, clen);
                let mut want = vec![T::ZERO; flen];
                upsample_ref(&src, &cshape, ax, &ops.r, &mut want);
                let mut got = vec![T::ZERO; flen];
                axis::upsample(&src, &cshape, ax, &ops.r, &mut got);
                assert_eq!(bits(&got), bits(&want), "upsample {tag}");
                let mut got = vec![T::ZERO; flen];
                axis::upsample_with(&src, &cshape, ax, &ops.r, &mut got, 3);
                assert_eq!(bits(&got), bits(&want), "upsample_with(3) {tag}");

                // LPK mass-trans
                let src: Vec<T> = randv(&mut rng, flen);
                let mut want = vec![T::ZERO; clen];
                masstrans_ref(&src, &fshape, ax, &ops, &mut want);
                let mut got = vec![T::ZERO; clen];
                axis::masstrans(&src, &fshape, ax, &ops, &mut got);
                assert_eq!(bits(&got), bits(&want), "masstrans {tag}");
                let mut got = vec![T::ZERO; clen];
                axis::masstrans_with(&src, &fshape, ax, &ops, &mut got, 3);
                assert_eq!(bits(&got), bits(&want), "masstrans_with(3) {tag}");

                // IPK Thomas (in place on the coarse array)
                let base: Vec<T> = randv(&mut rng, clen);
                let mut want = base.clone();
                thomas_ref(&mut want, &cshape, ax, &ops);
                let mut got = base.clone();
                axis::thomas(&mut got, &cshape, ax, &ops);
                assert_eq!(bits(&got), bits(&want), "thomas {tag}");
                let mut got = base.clone();
                axis::thomas_with(&mut got, &cshape, ax, &ops, 3);
                assert_eq!(bits(&got), bits(&want), "thomas_with(3) {tag}");
            }
        }
    }

    // Fused last-axis upsample-apply (only defined for the last axis).
    for mf in [5usize, 17] {
        for other in [4usize, 7] {
            let mc = (mf + 1) / 2;
            let xs = coords(&mut rng, mf);
            let ops: DimOps<T> = DimOps::new(&xs);
            let cshape = [other, other, mc];
            let clen: usize = cshape.iter().product();
            let flen = other * other * mf;
            let src: Vec<T> = randv(&mut rng, clen);
            let base: Vec<T> = randv(&mut rng, flen);
            for sign in [T::ONE, T::from_f64(-1.0)] {
                let mut want = base.clone();
                upsample_apply_last_ref(&src, &cshape, &ops.r, &mut want, sign);
                let mut got = base.clone();
                axis::upsample_apply_last(&src, &cshape, &ops.r, &mut got, sign);
                assert_eq!(
                    bits(&got),
                    bits(&want),
                    "upsample_apply_last mf={mf} other={other} bytes={}",
                    T::BYTES
                );
                let mut got = base.clone();
                axis::upsample_apply_last_with(&src, &cshape, &ops.r, &mut got, sign, 3);
                assert_eq!(
                    bits(&got),
                    bits(&want),
                    "upsample_apply_last_with(3) mf={mf} other={other} bytes={}",
                    T::BYTES
                );
            }
        }
    }
}

#[test]
fn kernels_bit_identical_f64() {
    kernel_matrix::<f64>(0xC0_FE_01);
}

#[test]
fn kernels_bit_identical_f32() {
    kernel_matrix::<f32>(0xC0_FE_02);
}

// ---- quantize / dequantize vs plain serial loops ----

fn quant_matrix<T: Scalar>(seed: u64) {
    let mut rng = Rng::new(seed);
    let meta = QuantMeta::for_bound(1e-4, 7);
    let inv = 1.0 / meta.bin;
    // lengths straddling the 64-element probe blocks and odd remainders
    for n in [1usize, 63, 64, 65, 129, 1023, 10_000] {
        let data: Vec<T> = randv(&mut rng, n);
        let got = quantize(&data, &meta).expect("finite input quantizes");
        let mut want = Vec::with_capacity(n);
        for v in &data {
            want.push((v.to_f64() * inv).round() as i64);
        }
        assert_eq!(got, want, "quantize n={n} bytes={}", T::BYTES);

        let back: Vec<T> = dequantize(&got, &meta);
        let mut back_ref = Vec::with_capacity(n);
        for &k in &got {
            back_ref.push(T::from_f64(k as f64 * meta.bin));
        }
        assert_eq!(
            bits(&back),
            bits(&back_ref),
            "dequantize n={n} bytes={}",
            T::BYTES
        );
        for (orig, rec) in data.iter().zip(&back) {
            assert!(
                (orig.to_f64() - rec.to_f64()).abs() <= meta.bin * 0.5 + 1e-12,
                "bin-width bound violated"
            );
        }
    }
}

#[test]
fn quantize_matches_serial_reference_f64() {
    quant_matrix::<f64>(0xDE_AD_01);
}

#[test]
fn quantize_matches_serial_reference_f32() {
    quant_matrix::<f32>(0xDE_AD_02);
}
