//! Sharded-vs-unsharded equivalence and the region-of-interest I/O
//! acceptance property.
//!
//! Per-block refactoring uses a *different* hierarchy than a
//! whole-domain refactor (each slab decomposes independently), so the
//! meaningful bit-identity contract is against the **per-slab unsharded
//! baseline**: for every dtype × codec, `Sharded::retrieve` must equal
//! — bitwise — refactoring and retrieving every slab with a plain
//! `Session` and reassembling with `assemble_slabs`. (For a one-block
//! shard the slab *is* the domain, and the shard is bitwise identical
//! to the plain `Session::refactor` + `retrieve` path — asserted in
//! `rust/src/api/sharded.rs` unit tests.)
//!
//! The I/O side: on a GrayScott-sized 33³ volume split into 4 blocks,
//! `retrieve_region` must read **only the intersecting blocks' bytes**,
//! asserted exactly via the `bytes_read` counters.

use mgr::api::{AnyTensor, Dtype, Fidelity, Session, Sharded};
use mgr::compress::Codec;
use mgr::coordinator::{assemble_slabs, extract_slab, partition_slabs, Slab};
use mgr::grid::Tensor;
use mgr::sim::GrayScott;
use mgr::util::stats::value_range;

fn smooth(shape: &[usize]) -> AnyTensor {
    Tensor::<f64>::from_fn(shape, |idx| {
        idx.iter()
            .enumerate()
            .map(|(d, &i)| ((d + 2) as f64 * i as f64 * 0.17).sin())
            .sum()
    })
    .into()
}

/// The unsharded baseline: refactor + retrieve every slab with a plain
/// per-block [`Session`], reassemble with [`assemble_slabs`].
fn per_slab_baseline(
    data: &AnyTensor,
    axis: usize,
    blocks: usize,
    codec: Codec,
    eb: f64,
    fidelity: Fidelity,
) -> AnyTensor {
    let shape = data.shape().to_vec();
    let slabs = partition_slabs(&shape, axis, blocks).unwrap();
    let block_session = |bshape: &[usize], dtype: Dtype| {
        Session::builder()
            .shape(bshape)
            .dtype(dtype)
            .codec(codec)
            .error_bound(eb)
            .build()
            .unwrap()
    };
    match data {
        AnyTensor::F64(t) => {
            let parts: Vec<(Slab, Tensor<f64>)> = slabs
                .iter()
                .map(|s| {
                    let block = extract_slab(t, s);
                    let sess = block_session(block.shape(), Dtype::F64);
                    let r = sess.refactor(&block.clone().into()).unwrap();
                    let back = r.retrieve(fidelity).unwrap();
                    (s.clone(), back.as_f64().unwrap().clone())
                })
                .collect();
            AnyTensor::F64(assemble_slabs(&shape, &parts))
        }
        AnyTensor::F32(t) => {
            let parts: Vec<(Slab, Tensor<f32>)> = slabs
                .iter()
                .map(|s| {
                    let block = extract_slab(t, s);
                    let sess = block_session(block.shape(), Dtype::F32);
                    let r = sess.refactor(&block.clone().into()).unwrap();
                    let back = r.retrieve(fidelity).unwrap();
                    (s.clone(), back.as_f32().unwrap().clone())
                })
                .collect();
            AnyTensor::F32(assemble_slabs(&shape, &parts))
        }
    }
}

#[test]
fn sharded_retrieve_is_bitwise_the_per_slab_baseline_for_every_dtype_and_codec() {
    let shape = [17usize, 17];
    let eb = 1e-3;
    for dtype in [Dtype::F64, Dtype::F32] {
        for codec in [Codec::Zlib, Codec::HuffRle] {
            let data = smooth(&shape).cast(dtype);
            let session = Session::builder()
                .shape(&shape)
                .dtype(dtype)
                .codec(codec)
                .error_bound(eb)
                .build()
                .unwrap();
            let sharded = session.refactor_sharded(&data, 4).unwrap();

            for fidelity in [Fidelity::All, Fidelity::Classes(1), Fidelity::Classes(2)] {
                let got = sharded.retrieve(fidelity).unwrap();
                let want = per_slab_baseline(&data, 0, 4, codec, eb, fidelity);
                assert_eq!(got, want, "{dtype:?} {codec:?} {fidelity:?}");
            }
            // full fidelity preserves the producer's error bound globally
            let full = sharded.retrieve(Fidelity::All).unwrap();
            assert!(
                full.linf_to(&data).unwrap() <= eb,
                "{dtype:?} {codec:?} violates eb"
            );
        }
    }
}

#[test]
fn grayscott_region_reads_only_the_intersecting_blocks_bytes() {
    let n = 33;
    let mut sim = GrayScott::new(n, 7);
    sim.step(100);
    let raw = sim.v_field();
    let eb = 1e-3 * value_range(raw.data());
    let shape = raw.shape().to_vec();
    let field: AnyTensor = raw.into();

    let session = Session::builder().shape(&shape).error_bound(eb).build().unwrap();
    // 4 blocks along axis 0: slabs [0..9), [8..17), [16..25), [24..33)
    let sharded = session.refactor_sharded(&field, 4).unwrap();
    let path = std::env::temp_dir().join("mgr_shard_acceptance.mgrs");
    sharded.store_file(&path).unwrap();

    // lazy open fetches the index alone
    let lazy = Sharded::open_file(&path).unwrap();
    assert_eq!(lazy.bytes_read(), lazy.index_bytes());

    // a region strictly inside block 2 opens block 2 and nothing else
    let roi = [18..23, 4..29, 0..33];
    assert_eq!(lazy.blocks_for_region(&roi).unwrap(), vec![2]);
    let region = lazy.retrieve_region(&roi, Fidelity::All).unwrap();
    assert_eq!(region.shape(), &[5, 25, 33]);
    let after_region = lazy.bytes_read();
    // exact accounting: the index plus block 2's whole container —
    // no other block's bytes (not even their headers) left the disk
    assert_eq!(
        after_region,
        lazy.index_bytes() + lazy.header().blocks[2].bytes,
        "region read must touch exactly the intersecting block"
    );
    assert!(after_region < lazy.total_bytes());

    // a full retrieve on a fresh open reads strictly more
    let full_open = Sharded::open_file(&path).unwrap();
    let full = full_open.retrieve(Fidelity::All).unwrap();
    assert_eq!(full_open.bytes_read(), full_open.total_bytes());
    assert!(after_region < full_open.bytes_read());

    // the region equals the full retrieve, sliced — bitwise
    let f = full.as_f64().unwrap();
    let r = region.as_f64().unwrap();
    for i in 0..5 {
        for j in 0..25 {
            for k in 0..n {
                assert_eq!(
                    r.get(&[i, j, k]),
                    f.get(&[18 + i, 4 + j, k]),
                    "({i},{j},{k})"
                );
            }
        }
    }
    // and the full-fidelity reconstruction honors the bound
    assert!(full.linf_to(&field).unwrap() <= eb);
    std::fs::remove_file(&path).ok();
}

#[test]
fn boundary_node_region_opens_both_neighbours_and_coarse_regions_read_less() {
    let shape = [17usize, 9];
    let session = Session::builder().shape(&shape).build().unwrap();
    let sharded = session.refactor_sharded(&smooth(&shape), 2).unwrap();
    let path = std::env::temp_dir().join("mgr_shard_boundary.mgrs");
    sharded.store_file(&path).unwrap();

    // node 8 is shared: a region covering only it must open both blocks
    let lazy = Sharded::open_file(&path).unwrap();
    assert_eq!(lazy.blocks_for_region(&[8..9, 0..9]).unwrap(), vec![0, 1]);
    lazy.retrieve_region(&[8..9, 0..9], Fidelity::All).unwrap();
    assert_eq!(lazy.bytes_read(), lazy.total_bytes());

    // a coarse (1-class) region on one block reads less than that
    // block's full container: per-class laziness composes with sharding
    let coarse = Sharded::open_file(&path).unwrap();
    coarse
        .retrieve_region(&[0..5, 0..9], Fidelity::Classes(1))
        .unwrap();
    assert!(
        coarse.bytes_read() < coarse.index_bytes() + coarse.header().blocks[0].bytes,
        "1-class region must not read block 0 whole"
    );
    std::fs::remove_file(&path).ok();
}
