//! Fault-injection sweep for tiered-storage execution: every induced
//! failure must surface as a *typed* [`ExecError`], leave the source
//! artifact untouched, leave no half-move behind (except the documented
//! torn state of a simulated crash), and recover by simply re-running
//! the execution — idempotently.

use std::io::Read;
use std::path::{Path, PathBuf};

use mgr::api::{Dtype, Session};
use mgr::grid::Tensor;
use mgr::storage::exec::{
    class_sizes, ExecError, ExecFault, TierExecutor, TierManifest, TierRoot, TieredReader,
};
use mgr::storage::{place_classes, StorageTier, TierSpec};

fn tmp_base(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "mgr_fuzz_tier_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// A refactored container on disk plus a placement that spreads its
/// classes over all three tiers.
fn fixture(base: &Path) -> (PathBuf, Vec<u8>, mgr::storage::Placement, Vec<TierRoot>) {
    let session = Session::builder()
        .shape(&[33, 33])
        .dtype(Dtype::F64)
        .build()
        .unwrap();
    let field = Tensor::<f64>::from_fn(&[33, 33], |idx| {
        (idx[0] as f64 * 0.23).sin() * (idx[1] as f64 * 0.19).cos()
    })
    .into();
    let r = session.refactor(&field).unwrap();
    let path = base.join("f.mgr");
    session.store_file(&r, &path).unwrap();
    let original = std::fs::read(&path).unwrap();

    let sizes = class_sizes(&path).unwrap();
    let middle: u64 = sizes[1..sizes.len() - 1].iter().sum();
    let specs = vec![
        TierSpec {
            capacity: sizes[0],
            ..TierSpec::burst_buffer()
        },
        TierSpec {
            capacity: middle,
            ..TierSpec::parallel_fs()
        },
        TierSpec::archive(),
    ];
    let placement = place_classes(&sizes, &specs);
    assert!(placement.over_capacity.is_empty());
    let roots = vec![
        TierRoot::new(StorageTier::BurstBuffer, base.join("bb")),
        TierRoot::new(StorageTier::ParallelFs, base.join("pfs")),
        TierRoot::new(StorageTier::Archive, base.join("ar")),
    ];
    (path, original, placement, roots)
}

fn dir_file_count(dir: &Path) -> usize {
    std::fs::read_dir(dir).map(|d| d.count()).unwrap_or(0)
}

fn roundtrips(path: &Path, original: &[u8]) {
    let reader = TieredReader::open(TierManifest::path_for(path)).unwrap();
    let mut back = Vec::new();
    reader.source().read_to_end(&mut back).unwrap();
    assert_eq!(back, original, "tiered stream must match the artifact");
}

#[test]
fn deleted_tier_root_is_a_typed_io_error_and_rerun_recovers() {
    let base = tmp_base("delroot");
    let (path, original, placement, roots) = fixture(&base);
    let pfs_dir = roots[1].root.clone();
    let exec = TierExecutor::new(roots).unwrap();

    // the tier vanishes between wiring and execution (unmounted mid-move)
    std::fs::remove_dir_all(&pfs_dir).unwrap();
    let err = exec.execute(&placement, &path).unwrap_err();
    assert!(matches!(err, ExecError::Io { .. }), "got {err:?}");
    assert!(err.to_string().contains("segment"), "{err}");
    assert!(std::error::Error::source(&err).is_some(), "chain must survive");

    // the source artifact is untouched and no half-move was left behind
    assert_eq!(std::fs::read(&path).unwrap(), original);
    assert_eq!(dir_file_count(&base.join("bb")), 0);
    assert_eq!(dir_file_count(&base.join("ar")), 0);
    assert!(!TierManifest::path_for(&path).exists());

    // recovery: restore the root and simply re-run
    std::fs::create_dir_all(&pfs_dir).unwrap();
    exec.execute(&placement, &path).unwrap();
    roundtrips(&path, &original);
    std::fs::remove_dir_all(&base).ok();
}

#[test]
fn truncated_or_missing_segment_is_typed_and_reexecution_repairs() {
    let base = tmp_base("trunc");
    let (path, original, placement, roots) = fixture(&base);
    let exec = TierExecutor::new(roots).unwrap();
    let manifest = exec.execute(&placement, &path).unwrap();

    // truncate the finest class's segment file behind the manifest's back
    let victim = &manifest.classes.last().unwrap().file;
    let bytes = std::fs::read(victim).unwrap();
    assert!(bytes.len() > 1);
    std::fs::write(victim, &bytes[..bytes.len() - 1]).unwrap();
    let err = TieredReader::open(TierManifest::path_for(&path)).unwrap_err();
    assert!(matches!(err, ExecError::Manifest(_)), "got {err:?}");
    assert!(err.to_string().contains("truncated or stale"), "{err}");

    // a *missing* segment is typed too
    std::fs::remove_file(victim).unwrap();
    let err = TieredReader::open(TierManifest::path_for(&path)).unwrap_err();
    assert!(matches!(err, ExecError::Io { .. }), "got {err:?}");

    // recovery is one idempotent re-run over the stale files
    exec.execute(&placement, &path).unwrap();
    roundtrips(&path, &original);
    assert_eq!(std::fs::read(&path).unwrap(), original);
    std::fs::remove_dir_all(&base).ok();
}

#[cfg(unix)]
#[test]
fn read_only_destination_is_typed_and_leaves_no_partial_move() {
    use std::os::unix::fs::PermissionsExt;
    let base = tmp_base("rodir");
    let (path, original, placement, roots) = fixture(&base);
    let ar_dir = roots[2].root.clone();
    let exec = TierExecutor::new(roots).unwrap();

    std::fs::set_permissions(&ar_dir, std::fs::Permissions::from_mode(0o555)).unwrap();
    // privileged runs (root in CI containers) ignore directory modes —
    // probe, and skip the scenario when the fault cannot be induced
    let probe = ar_dir.join(".probe");
    if std::fs::File::create(&probe).is_ok() {
        let _ = std::fs::remove_file(&probe);
        let _ = std::fs::set_permissions(&ar_dir, std::fs::Permissions::from_mode(0o755));
        eprintln!("skipping: running with privileges that bypass read-only dirs");
        std::fs::remove_dir_all(&base).ok();
        return;
    }

    let err = exec.execute(&placement, &path).unwrap_err();
    assert!(matches!(err, ExecError::Io { .. }), "got {err:?}");
    assert!(err.to_string().contains("creating segment file"), "{err}");

    // source untouched; the files created on the writable tiers before
    // the failure were cleaned up
    assert_eq!(std::fs::read(&path).unwrap(), original);
    assert_eq!(dir_file_count(&base.join("bb")), 0);
    assert_eq!(dir_file_count(&base.join("pfs")), 0);
    assert!(!TierManifest::path_for(&path).exists());

    // recovery: restore write permission and re-run
    std::fs::set_permissions(&ar_dir, std::fs::Permissions::from_mode(0o755)).unwrap();
    exec.execute(&placement, &path).unwrap();
    roundtrips(&path, &original);
    std::fs::remove_dir_all(&base).ok();
}

#[test]
fn crash_before_manifest_commit_is_recoverable_by_rerunning() {
    let base = tmp_base("crash");
    let (path, original, placement, roots) = fixture(&base);
    let exec = TierExecutor::new(roots).unwrap();

    // simulate a crash after every segment copy but before the commit
    let err = exec
        .execute_faulted(&placement, &path, ExecFault::BeforeManifestCommit)
        .unwrap_err();
    assert!(matches!(err, ExecError::Interrupted(_)), "got {err:?}");

    // the torn state a real crash leaves: segment files exist, but the
    // manifest does not reference them (it was never committed)
    assert!(!TierManifest::path_for(&path).exists());
    let torn: usize = [base.join("bb"), base.join("pfs"), base.join("ar")]
        .iter()
        .map(|d| dir_file_count(d.as_path()))
        .sum();
    assert!(torn > 0, "crash must leave the copied segments behind");
    assert_eq!(std::fs::read(&path).unwrap(), original, "source untouched");

    // recovery: a plain re-run overwrites the torn files and commits
    let manifest = exec.execute(&placement, &path).unwrap();
    assert_eq!(manifest.total_bytes as usize, original.len());
    roundtrips(&path, &original);

    // and re-running again over committed state is idempotent
    exec.execute(&placement, &path).unwrap();
    roundtrips(&path, &original);
    std::fs::remove_dir_all(&base).ok();
}
