//! Acceptance matrix for `mgr reencode` (`api::reencode`): the three
//! structurally-cheap conversions, exercised through the public facade
//! across dtype × codec.
//!
//! * truncation: the truncated artifact retrieves **bit-identically**
//!   to `Fidelity::Classes(K)` on the original;
//! * same-grid reencode at full fidelity is the byte-level identity;
//! * re-tiling onto a grid that shares no extents is byte-identical to
//!   `ShardWriter::write_grid` on the full reconstruction (with the
//!   input's own error bound / level cap / codec), and its compounded
//!   error stays within 2·eb of the original field;
//! * a single-block N-D region of interest reads exactly the index
//!   plus that one block's bytes.

use std::io::Cursor;

use mgr::api::reencode::{reencode, ReencodeSpec};
use mgr::api::{AnyTensor, Fidelity, OpenContainer, Session, Sharded};
use mgr::compress::Codec;
use mgr::coordinator::assemble_blocks;
use mgr::grid::Tensor;
use mgr::storage::container::ContainerHeader;
use mgr::storage::shard::is_shard;
use mgr::storage::{BlockMeta, ProgressiveReader, ShardHeader, ShardWriter};
use mgr::util::{stats, Scalar};

fn smooth<T: Scalar>(shape: &[usize]) -> Tensor<T> {
    Tensor::from_fn(shape, |idx| {
        T::from_f64(
            idx.iter()
                .enumerate()
                .map(|(d, &i)| ((d + 2) as f64 * i as f64 * 0.23).sin())
                .sum(),
        )
    })
}

fn slice<'a>(bytes: &'a [u8], b: &BlockMeta) -> &'a [u8] {
    &bytes[b.offset as usize..(b.offset + b.bytes) as usize]
}

#[test]
fn truncated_shard_retrieves_like_classes_k() {
    for codec in [Codec::Zlib, Codec::HuffRle] {
        let t = smooth::<f64>(&[17, 9]);
        let (bytes, sh) = ShardWriter::<f64>::new(codec, 2)
            .write_grid(&t, &[2, 2], 1e-3)
            .unwrap();
        let original = Sharded::from_bytes(bytes.clone()).unwrap();
        let want = original.retrieve(Fidelity::Classes(2)).unwrap();

        let spec = ReencodeSpec {
            fidelity: Fidelity::Classes(2),
            ..Default::default()
        };
        let (out, report) = reencode(&bytes, &spec).unwrap();
        assert_eq!(report.blocks_copied, sh.nblocks(), "{codec:?}: pure byte copies");
        assert_eq!(report.bytes_decoded, 0, "{codec:?}: truncation never decodes");
        assert!(report.bytes_out < report.bytes_in, "{codec:?}");

        // the truncated shard's *full* retrieval is the original's
        // Classes(2) retrieval, bitwise
        let truncated = Sharded::from_bytes(out).unwrap();
        let got = truncated.retrieve(Fidelity::All).unwrap();
        assert_eq!(got, want, "{codec:?}");
    }
}

#[test]
fn truncated_container_retrieves_like_classes_k_via_the_session() {
    let session = Session::builder().shape(&[17, 17]).build().unwrap();
    let field: AnyTensor = smooth::<f64>(&[17, 17]).into();
    let refactored = session.refactor(&field).unwrap();
    let want = session.retrieve(&refactored, Fidelity::Classes(2)).unwrap();

    let spec = ReencodeSpec {
        fidelity: Fidelity::Classes(2),
        ..Default::default()
    };
    let (out, report) = session.reencode(refactored.as_bytes(), &spec).unwrap();
    assert_eq!(report.bytes_decoded, 0);
    let container = OpenContainer::open(Cursor::new(out)).unwrap();
    let got = container.retrieve(Fidelity::All).unwrap();
    assert_eq!(got.tensor(), &want, "truncated artifact == Classes(2) retrieval");
}

#[test]
fn identical_grid_reencode_is_the_byte_identity() {
    for codec in [Codec::Zlib, Codec::HuffRle] {
        let t = smooth::<f64>(&[17, 9]);
        let (bytes, sh) = ShardWriter::<f64>::new(codec, 2)
            .write_grid(&t, &[2, 2], 1e-3)
            .unwrap();
        let spec = ReencodeSpec {
            blocks_per_axis: Some(vec![2, 2]),
            ..Default::default()
        };
        let (out, report) = reencode(&bytes, &spec).unwrap();
        assert_eq!(out, bytes, "{codec:?}: same grid + full fidelity is the identity");
        assert_eq!(report.blocks_copied, sh.nblocks(), "{codec:?}");
        assert_eq!(report.bytes_decoded, 0, "{codec:?}");
    }
}

fn retile_case<T: Scalar>(codec: Codec) {
    let t = smooth::<T>(&[17, 9]);
    let (bytes, sh) = ShardWriter::<T>::new(codec, 2)
        .write_grid(&t, &[2, 2], 1e-3)
        .unwrap();
    // [2, 1] shares no extent with [2, 2]: every output block is cut
    // fresh — the pure re-tile path, with nothing byte-copied
    let spec = ReencodeSpec {
        blocks_per_axis: Some(vec![2, 1]),
        ..Default::default()
    };
    let (out, report) = reencode(&bytes, &spec).unwrap();
    assert!(is_shard(&out));
    assert_eq!(report.blocks_in, 4, "{codec:?}");
    assert_eq!(report.blocks_out, 2, "{codec:?}");
    assert_eq!(report.blocks_copied, 0, "{codec:?}: no shared extents");
    assert!(report.bytes_decoded > 0, "{codec:?}");

    // comparator: the full reconstruction re-sharded by write_grid with
    // the input's own parameters (eb, level cap, codec) — the re-tile
    // must land on these bytes exactly
    let mut parts = Vec::new();
    for k in 0..sh.nblocks() {
        let mut r = ProgressiveReader::<T>::open(slice(&bytes, &sh.blocks[k])).unwrap();
        let n = r.nclasses();
        parts.push((sh.extent(k), r.retrieve(n).unwrap()));
    }
    let full = assemble_blocks(&sh.shape, &parts);
    let (h0, _) = ContainerHeader::parse(slice(&bytes, &sh.blocks[0])).unwrap();
    let (want, _) = ShardWriter::<T>::new(codec, 1)
        .with_nlevels(h0.nlevels)
        .write_grid(&full, &[2, 1], h0.quant.error_bound)
        .unwrap();
    assert_eq!(out, want, "{codec:?}: re-tile == write_grid on the reconstruction");

    // compounded error: one quantize-dequantize round trip on top of
    // the original refactoring stays within 2·eb of the source field
    let (sh2, _) = ShardHeader::parse(&out).unwrap();
    let mut parts = Vec::new();
    for k in 0..sh2.nblocks() {
        let mut r = ProgressiveReader::<T>::open(slice(&out, &sh2.blocks[k])).unwrap();
        let n = r.nclasses();
        parts.push((sh2.extent(k), r.retrieve(n).unwrap()));
    }
    let got = assemble_blocks(&sh2.shape, &parts);
    let got64: Vec<f64> = got.data().iter().map(|v| v.to_f64()).collect();
    let src64: Vec<f64> = t.data().iter().map(|v| v.to_f64()).collect();
    assert!(
        stats::linf(&got64, &src64) <= 2e-3,
        "{codec:?}: compounded error must stay within 2·eb"
    );
}

#[test]
fn retile_matches_write_grid_for_every_dtype_and_codec() {
    retile_case::<f64>(Codec::Zlib);
    retile_case::<f64>(Codec::HuffRle);
    retile_case::<f32>(Codec::Zlib);
    retile_case::<f32>(Codec::HuffRle);
}

#[test]
fn shard_codec_recode_roundtrips_to_the_original_bytes() {
    let t = smooth::<f64>(&[17, 9]);
    let (bytes, sh) = ShardWriter::<f64>::new(Codec::Zlib, 2)
        .write_grid(&t, &[2, 2], 1e-3)
        .unwrap();
    let there = ReencodeSpec {
        codec: Some(Codec::HuffRle),
        ..Default::default()
    };
    let (out, report) = reencode(&bytes, &there).unwrap();
    assert_eq!(report.blocks_copied, 0);
    assert!(report.bytes_decoded > 0);

    // retrieval is invariant under the entropy stage
    let want = Sharded::from_bytes(bytes.clone())
        .unwrap()
        .retrieve(Fidelity::All)
        .unwrap();
    let got = Sharded::from_bytes(out.clone())
        .unwrap()
        .retrieve(Fidelity::All)
        .unwrap();
    assert_eq!(got, want, "entropy recode must be lossless");

    // every block landed on the new codec
    let (sh2, _) = ShardHeader::parse(&out).unwrap();
    assert_eq!(sh2.nblocks(), sh.nblocks());
    for b in &sh2.blocks {
        let (h, _) = ContainerHeader::parse(slice(&out, b)).unwrap();
        assert_eq!(h.codec, Codec::HuffRle);
    }

    // ... and converting back lands on the original artifact, bitwise
    let back = ReencodeSpec {
        codec: Some(Codec::Zlib),
        ..Default::default()
    };
    let (again, _) = reencode(&out, &back).unwrap();
    assert_eq!(again, bytes);
}

#[test]
fn single_block_nd_roi_reads_exactly_index_plus_one_block() {
    let t = smooth::<f64>(&[17, 9]);
    let (bytes, sh) = ShardWriter::<f64>::new(Codec::Zlib, 2)
        .write_grid(&t, &[2, 2], 1e-3)
        .unwrap();
    let sharded = Sharded::from_bytes(bytes).unwrap();
    // [10..15, 5..8] lies strictly inside block 3 (extent [8..17, 4..9])
    // — it avoids every shared boundary plane, so exactly one block's
    // bytes may be touched on top of the index
    sharded
        .retrieve_region(&[10..15, 5..8], Fidelity::All)
        .unwrap();
    assert_eq!(
        sharded.bytes_read(),
        sharded.index_bytes() + sh.blocks[3].bytes,
        "a single-block ROI opens exactly the index + that block"
    );
    assert!(sharded.bytes_read() < sharded.total_bytes());
}
