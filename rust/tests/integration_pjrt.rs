//! Cross-layer integration: the AOT-compiled HLO artifacts (L1 Pallas +
//! L2 JAX) executed through PJRT must agree with the native Rust core on
//! every operation, and PJRT decompose/recompose must round-trip.
//!
//! Requires `make artifacts` to have run AND the crate to be built with
//! the `pjrt` feature (see rust/src/runtime/mod.rs) — without it this
//! whole test file compiles away.
#![cfg(feature = "pjrt")]

use mgr::grid::{Hierarchy, Tensor};
use mgr::refactor::Refactorer;
use mgr::runtime::EngineHandle;
use mgr::util::rng::Rng;
use mgr::util::stats::linf;

fn engine() -> EngineHandle {
    EngineHandle::spawn("artifacts".into()).expect(
        "artifacts/ missing or invalid — run `make artifacts` before `cargo test`",
    )
}

fn random_f32(shape: &[usize], seed: u64) -> Tensor<f32> {
    let mut rng = Rng::new(seed);
    Tensor::from_fn(shape, |_| rng.normal() as f32)
}

#[test]
fn decompose_artifacts_match_native_core() {
    let engine = engine();
    for v in engine.variants().unwrap() {
        if v.op != "decompose" || v.dtype != "float32" {
            continue;
        }
        // keep the test fast: skip the largest variants here (the
        // pjrt-check CLI covers all of them)
        if v.shape.iter().product::<usize>() > 40_000 {
            continue;
        }
        let h = Hierarchy::uniform(&v.shape);
        let t = random_f32(&v.shape, 1);
        let got = engine.run(&v.name, &t, &h.coords().to_vec()).unwrap();
        let mut want = t.clone();
        Refactorer::new(h).decompose(&mut want);
        let err = linf(got.data(), want.data());
        assert!(err < 2e-3, "{}: PJRT vs native L∞ = {err}", v.name);
    }
}

#[test]
fn pjrt_roundtrip_is_identity() {
    let engine = engine();
    let shape = [17usize, 17, 17];
    let h = Hierarchy::uniform(&shape);
    let coords = h.coords().to_vec();
    let t = random_f32(&shape, 2);
    let dec_name = engine
        .find("decompose", &shape, "float32")
        .unwrap()
        .expect("17^3 f32 decompose artifact");
    let rec_name = engine
        .find("recompose", &shape, "float32")
        .unwrap()
        .expect("17^3 f32 recompose artifact");
    let dec = engine.run(&dec_name, &t, &coords).unwrap();
    let back = engine.run(&rec_name, &dec, &coords).unwrap();
    let err = linf(back.data(), t.data());
    assert!(err < 1e-4, "PJRT roundtrip L∞ = {err}");
}

#[test]
fn pjrt_f64_matches_native_tightly() {
    let engine = engine();
    let shape = [33usize, 33, 33];
    let Some(name) = engine.find("decompose", &shape, "float64").unwrap() else {
        panic!("33^3 f64 artifact missing");
    };
    let h = Hierarchy::uniform(&shape);
    let mut rng = Rng::new(3);
    let t = Tensor::from_fn(&shape, |_| rng.normal());
    let got = engine.run(&name, &t, &h.coords().to_vec()).unwrap();
    let mut want = t.clone();
    Refactorer::new(h).decompose(&mut want);
    let err = linf(got.data(), want.data());
    assert!(err < 1e-10, "f64 PJRT vs native L∞ = {err}");
}

#[test]
fn pjrt_spatiotemporal_roundtrip() {
    let engine = engine();
    let shape = [5usize, 17, 17, 17];
    let h = Hierarchy::uniform(&shape);
    let coords = h.coords().to_vec();
    let t = random_f32(&shape, 4);
    let dec = engine
        .find("st_decompose", &shape, "float32")
        .unwrap()
        .expect("st_decompose artifact");
    let rec = engine
        .find("st_recompose", &shape, "float32")
        .unwrap()
        .expect("st_recompose artifact");
    let d = engine.run(&dec, &t, &coords).unwrap();
    let back = engine.run(&rec, &d, &coords).unwrap();
    let err = linf(back.data(), t.data());
    assert!(err < 1e-4, "spatiotemporal PJRT roundtrip L∞ = {err}");

    // and the spatiotemporal artifact must match the native st engine
    let mut want = t.clone();
    Refactorer::spatiotemporal(h).decompose(&mut want);
    let err = linf(d.data(), want.data());
    assert!(err < 2e-3, "st PJRT vs native L∞ = {err}");
}

#[test]
fn pjrt_nonuniform_coords_supported() {
    // coordinates are runtime inputs: the same artifact must serve a
    // non-uniform grid
    let engine = engine();
    let shape = [17usize, 17, 17];
    let mut rng = Rng::new(5);
    let coords: Vec<Vec<f64>> = shape.iter().map(|&m| rng.coords(m)).collect();
    let h = Hierarchy::new(&shape, coords.clone(), None);
    let t = random_f32(&shape, 6);
    let name = engine
        .find("decompose", &shape, "float32")
        .unwrap()
        .unwrap();
    let got = engine.run(&name, &t, &coords).unwrap();
    let mut want = t.clone();
    Refactorer::new(h).decompose(&mut want);
    let err = linf(got.data(), want.data());
    assert!(err < 2e-3, "non-uniform PJRT vs native L∞ = {err}");
}

#[test]
fn engine_handle_is_send_and_shared() {
    // the coordinator uses the handle from multiple worker threads
    let engine = engine();
    let shape = [17usize, 17, 17];
    let h = Hierarchy::uniform(&shape);
    let name = engine
        .find("decompose", &shape, "float32")
        .unwrap()
        .unwrap();
    engine.warm(&name).unwrap();
    crossbeam_utils::thread::scope(|s| {
        for seed in 0..4u64 {
            let engine = engine.clone();
            let name = name.clone();
            let coords = h.coords().to_vec();
            s.spawn(move |_| {
                let t = random_f32(&shape, 10 + seed);
                let out = engine.run(&name, &t, &coords).unwrap();
                assert_eq!(out.shape(), &shape);
            });
        }
    })
    .unwrap();
}
