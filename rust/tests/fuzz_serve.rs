//! Adversarial fuzzing of the `mgr serve` wire front, in the style of
//! `tests/fuzz_shard.rs`: truncated frames, oversized declared lengths,
//! garbage verbs, and mid-request disconnects. The contract under test:
//! every malformed input yields a **typed** error (a `PROTOCOL` status
//! response where framing still permits one) or a contained connection
//! drop — the daemon must never panic, and it must keep serving
//! well-formed requests on other (and, where framing is intact, the
//! same) connections throughout.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use mgr::api::{AnyTensor, Fidelity, Session};
use mgr::grid::Tensor;
use mgr::serve::protocol::{
    decode_response, encode_request, read_frame, status, write_frame, Request, Response,
    ResponseKind, MAX_RESPONSE_LEN,
};
use mgr::serve::{Client, ClientError, ServeConfig, ServeTarget, Server};
use mgr::util::rng::Rng;

fn smooth(shape: &[usize]) -> AnyTensor {
    Tensor::<f64>::from_fn(shape, |idx| {
        idx.iter()
            .enumerate()
            .map(|(d, &i)| ((d + 2) as f64 * i as f64 * 0.23).sin())
            .sum()
    })
    .into()
}

/// A server over a `[2, 2]`-grid shard plus the serial full
/// reconstruction.
fn serve_grid_shard() -> (Server, AnyTensor) {
    let s = Session::builder().shape(&[17, 9]).build().unwrap();
    let sharded = s.refactor_sharded_grid(&smooth(&[17, 9]), &[2, 2]).unwrap();
    let want = sharded.retrieve(Fidelity::All).unwrap();
    let server = Server::start(
        ServeTarget::Shard(sharded),
        "127.0.0.1:0",
        ServeConfig::default(),
    )
    .unwrap();
    (server, want)
}

/// A server over a small container plus the serial baseline tensor.
fn serve_container() -> (Server, AnyTensor) {
    let s = Session::builder().shape(&[17, 17]).build().unwrap();
    let r = s.refactor(&smooth(&[17, 17])).unwrap();
    let want = r.retrieve(Fidelity::All).unwrap();
    let server = Server::start(
        ServeTarget::Container(r.open().unwrap()),
        "127.0.0.1:0",
        ServeConfig::default(),
    )
    .unwrap();
    (server, want)
}

/// The health probe every abuse scenario ends with: a fresh well-formed
/// client must still get the bit-exact reconstruction.
fn assert_daemon_serves(server: &Server, want: &AnyTensor) {
    let mut client = Client::connect(server.addr()).unwrap();
    let got = client.retrieve(Fidelity::All).unwrap();
    assert_eq!(&got.tensor, want, "daemon must keep serving after abuse");
}

/// Poll the server's stats until `pred` holds (the daemon notices a
/// dropped connection asynchronously).
fn wait_for(server: &Server, pred: impl Fn(&mgr::serve::ServeStats) -> bool) {
    for _ in 0..200 {
        if pred(&server.stats()) {
            return;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    panic!("stats never satisfied the predicate: {:?}", server.stats());
}

#[test]
fn truncated_frames_drop_the_connection_only() {
    let (server, want) = serve_container();
    // declare 100 bytes, send 3, hang up — a classic mid-request death
    for sent in [0usize, 1, 3] {
        let mut raw = TcpStream::connect(server.addr()).unwrap();
        raw.write_all(&100u32.to_le_bytes()).unwrap();
        raw.write_all(&vec![0x5a; sent]).unwrap();
        drop(raw);
        assert_daemon_serves(&server, &want);
    }
    // a partial length prefix alone must not wedge anything either
    let mut raw = TcpStream::connect(server.addr()).unwrap();
    raw.write_all(&[7u8, 0]).unwrap();
    drop(raw);
    assert_daemon_serves(&server, &want);
    wait_for(&server, |s| s.framing_errors >= 4);
    let stats = server.shutdown();
    assert!(stats.framing_errors >= 4, "{stats:?}");
    assert_eq!(stats.errors, 0, "typed-error path never fired: {stats:?}");
}

#[test]
fn oversized_declared_length_gets_typed_error_then_close() {
    let (server, want) = serve_container();
    for len in [u32::MAX, (64 * 1024) + 1, 1 << 30] {
        let mut raw = TcpStream::connect(server.addr()).unwrap();
        raw.write_all(&len.to_le_bytes()).unwrap();
        // the server answers with a PROTOCOL status before closing —
        // it must NOT try to allocate or read `len` bytes
        let body = read_frame(&mut raw, MAX_RESPONSE_LEN).unwrap().unwrap();
        match decode_response(&body, ResponseKind::Tensor).unwrap() {
            Response::Error { code, message } => {
                assert_eq!(code, status::PROTOCOL);
                assert!(message.contains("cap"), "{message}");
            }
            other => panic!("expected protocol error, got {other:?}"),
        }
        // ...and the connection is closed afterwards
        let mut probe = [0u8; 1];
        assert_eq!(raw.read(&mut probe).unwrap_or(0), 0, "connection must be closed");
        assert_daemon_serves(&server, &want);
    }
    server.shutdown();
}

#[test]
fn garbage_verbs_get_typed_errors_and_the_connection_keeps_serving() {
    let (server, want) = serve_container();
    let mut raw = TcpStream::connect(server.addr()).unwrap();
    // a parade of well-framed but undecodable bodies on ONE connection
    let bodies: Vec<Vec<u8>> = vec![
        vec![99],                                  // unknown verb
        vec![0],                                   // verb zero
        vec![1],                                   // retrieve, missing fidelity
        vec![1, 9, 0, 0, 0, 0, 0, 0, 0, 0],        // unknown fidelity tag
        vec![2, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0],     // region with zero rank
        {
            let mut b = encode_request(&Request::Stats);
            b.push(0xff); // trailing garbage
            b
        },
    ];
    for body in &bodies {
        write_frame(&mut raw, body).unwrap();
        let resp = read_frame(&mut raw, MAX_RESPONSE_LEN).unwrap().unwrap();
        match decode_response(&resp, ResponseKind::Tensor).unwrap() {
            Response::Error { code, .. } => assert_eq!(code, status::PROTOCOL, "{body:?}"),
            other => panic!("expected protocol error for {body:?}, got {other:?}"),
        }
    }
    // the SAME connection still serves a well-formed request afterwards
    write_frame(&mut raw, &encode_request(&Request::Retrieve(Fidelity::All))).unwrap();
    let resp = read_frame(&mut raw, MAX_RESPONSE_LEN).unwrap().unwrap();
    assert!(matches!(
        decode_response(&resp, ResponseKind::Tensor).unwrap(),
        Response::Tensor(_)
    ));
    drop(raw);
    assert_daemon_serves(&server, &want);
    let stats = server.shutdown();
    assert_eq!(stats.errors, bodies.len() as u64, "{stats:?}");
    assert!(stats.ok >= 2, "{stats:?}");
}

#[test]
fn random_mutations_of_valid_requests_never_kill_the_daemon() {
    let (server, want) = serve_container();
    let template = encode_request(&Request::Retrieve(Fidelity::Classes(2)));
    let mut rng = Rng::new(42);
    for round in 0..60 {
        let mut body = template.clone();
        match rng.below(3) {
            0 => {
                let i = rng.below(body.len());
                body[i] ^= 1 << rng.below(8);
            }
            1 => {
                let i = rng.below(body.len());
                body[i] = rng.below(256) as u8;
            }
            _ => {
                let i = rng.below(body.len());
                let l = 1 + rng.below(4).min(body.len() - i - 1);
                body.drain(i..i + l);
            }
        }
        let mut raw = TcpStream::connect(server.addr()).unwrap();
        write_frame(&mut raw, &body).unwrap();
        // whatever came back (a tensor for harmless mutations, a typed
        // error otherwise) must decode as a valid frame — or the server
        // legitimately closed on us; both are contained outcomes
        match read_frame(&mut raw, MAX_RESPONSE_LEN) {
            Ok(Some(resp)) => {
                decode_response(&resp, ResponseKind::Tensor).unwrap();
            }
            Ok(None) => {}
            Err(e) => panic!("round {round}: daemon sent garbage: {e}"),
        }
        drop(raw);
    }
    assert_daemon_serves(&server, &want);
    server.shutdown();
}

#[test]
fn fidelity_and_region_errors_are_typed_not_protocol() {
    // semantic failures travel as FIDELITY/REGION/USAGE — the fuzz
    // contract is that only *undecodable* bodies map to PROTOCOL
    let (server, want) = serve_container();
    let mut client = Client::connect(server.addr()).unwrap();
    match client.retrieve(Fidelity::Classes(0)) {
        Err(ClientError::Remote { code, .. }) => assert_eq!(code, status::FIDELITY),
        other => panic!("{other:?}"),
    }
    match client.retrieve(Fidelity::ByteBudget(1)) {
        Err(ClientError::Remote { code, .. }) => assert_eq!(code, status::FIDELITY),
        other => panic!("{other:?}"),
    }
    match client.retrieve_region(&[0..4], Fidelity::All) {
        Err(ClientError::Remote { code, .. }) => assert_eq!(code, status::USAGE),
        other => panic!("{other:?}"),
    }
    // the client survives its own rejected requests
    assert_eq!(client.retrieve(Fidelity::All).unwrap().tensor, want);
    drop(client);
    let stats = server.shutdown();
    assert_eq!(stats.errors, 3);
    assert_eq!(stats.framing_errors, 0);
}

#[test]
fn nd_region_abuse_gets_typed_errors_and_the_connection_keeps_serving() {
    let (server, want) = serve_grid_shard();
    let mut client = Client::connect(server.addr()).unwrap();

    // rank mismatches against the 2-D grid-sharded domain → REGION
    for roi in [vec![0u64..4], vec![0u64..4, 0..4, 0..4]] {
        match client.retrieve_region(&roi, Fidelity::All) {
            Err(ClientError::Remote { code, message }) => {
                assert_eq!(code, status::REGION, "{roi:?}");
                assert!(message.contains("dimension"), "{message}");
            }
            other => panic!("expected region error for {roi:?}, got {other:?}"),
        }
    }
    // out-of-grid ROIs on either axis → REGION, naming the axis bound
    for roi in [vec![0u64..99, 0..4], vec![0u64..17, 9..12]] {
        match client.retrieve_region(&roi, Fidelity::All) {
            Err(ClientError::Remote { code, message }) => {
                assert_eq!(code, status::REGION, "{roi:?}");
                assert!(message.contains("outside"), "{message}");
            }
            other => panic!("expected region error for {roi:?}, got {other:?}"),
        }
    }
    // astronomically large wire coordinates stay a typed REGION error
    match client.retrieve_region(&[(1u64 << 40)..(1 << 41), 0..4], Fidelity::All) {
        Err(ClientError::Remote { code, .. }) => assert_eq!(code, status::REGION),
        other => panic!("expected region error, got {other:?}"),
    }
    // the SAME client connection still serves after five rejections:
    // a full-domain ROI equals the full reconstruction, bit-exact
    let got = client
        .retrieve_region(&[0..17, 0..9], Fidelity::All)
        .unwrap();
    assert_eq!(got.tensor, want);
    drop(client);

    // reversed / empty bounds never reach the shard: decode_request
    // rejects them, so the reply is PROTOCOL, not REGION — and the raw
    // connection keeps serving afterwards
    let mut raw = TcpStream::connect(server.addr()).unwrap();
    for roi in [vec![5u64..3], vec![0u64..0, 0..9], vec![3u64..3, 0..9]] {
        let body = encode_request(&Request::RetrieveRegion(roi.clone(), Fidelity::All));
        write_frame(&mut raw, &body).unwrap();
        let resp = read_frame(&mut raw, MAX_RESPONSE_LEN).unwrap().unwrap();
        match decode_response(&resp, ResponseKind::Tensor).unwrap() {
            Response::Error { code, message } => {
                assert_eq!(code, status::PROTOCOL, "{roi:?}");
                assert!(message.contains("empty or inverted"), "{message}");
            }
            other => panic!("expected protocol error for {roi:?}, got {other:?}"),
        }
    }
    write_frame(&mut raw, &encode_request(&Request::Retrieve(Fidelity::All))).unwrap();
    let resp = read_frame(&mut raw, MAX_RESPONSE_LEN).unwrap().unwrap();
    assert!(matches!(
        decode_response(&resp, ResponseKind::Tensor).unwrap(),
        Response::Tensor(_)
    ));
    drop(raw);

    assert_daemon_serves(&server, &want);
    let stats = server.shutdown();
    assert_eq!(stats.errors, 8, "5 REGION + 3 PROTOCOL: {stats:?}");
    assert_eq!(stats.framing_errors, 0, "{stats:?}");
    assert!(stats.ok >= 3, "{stats:?}");
}

#[test]
fn stats_and_shutdown_survive_interleaved_abuse() {
    let (server, want) = serve_container();
    // abuse and legitimate traffic interleaved
    for i in 0..5 {
        let mut raw = TcpStream::connect(server.addr()).unwrap();
        raw.write_all(&(200u32 + i).to_le_bytes()).unwrap();
        drop(raw); // truncated frame
        assert_daemon_serves(&server, &want);
    }
    let mut client = Client::connect(server.addr()).unwrap();
    let json = client.stats().unwrap();
    assert!(json.contains("\"requests\":"), "{json}");
    client.shutdown_server().unwrap();
    let stats = server.wait();
    assert!(stats.ok >= 6, "{stats:?}"); // 5 probes + stats (+ shutdown ack)
}

#[test]
#[ignore = "long-loop stress variant; CI runs it in the dedicated --ignored job"]
fn stress_random_frame_garbage() {
    let (server, want) = serve_container();
    let mut rng = Rng::new(7);
    for _ in 0..400 {
        let len = rng.below(48);
        let garbage: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        let mut raw = TcpStream::connect(server.addr()).unwrap();
        // raw bytes straight onto the wire: sometimes a broken length
        // prefix, sometimes a broken body, sometimes nothing
        let _ = raw.write_all(&garbage);
        drop(raw);
    }
    assert_daemon_serves(&server, &want);
    server.shutdown();
}
