//! The central streaming invariants, swept across dtype × codec:
//!
//! 1. **Bit-identity** — every step retrieved from a `.mgrt` (whether
//!    it was committed independent or delta-coded) is bit-identical to
//!    refactoring that snapshot *standalone* through the same session
//!    and retrieving at the same fidelity. Delta coding happens in
//!    quantized-integer space, so `q_parent + Δ` reconstructs the
//!    child's quantized coefficients exactly — at every class prefix.
//! 2. **Error bound** — full-fidelity reconstruction of every step
//!    (independent or at the end of a delta chain) stays within the
//!    session's L∞ bound of the original snapshot; deltas never
//!    compound the error.
//! 3. **Backpressure** — the writer's measured high-water mark of
//!    resident snapshot bytes respects the `(window + 1) · step_bytes`
//!    bound, so a producer ahead of the encoder blocks instead of
//!    ballooning.

use std::io::{self, Cursor, Seek, SeekFrom, Write};
use std::sync::{Arc, Mutex};

use mgr::api::{AnyTensor, Dtype, Fidelity, Series, Session};
use mgr::compress::Codec;
use mgr::sim::GrayScott;
use mgr::storage::StepEncoding;

const SHAPE: [usize; 3] = [17, 17, 17];
const NSTEPS: usize = 5;
const WINDOW: usize = 2;

/// f32 quantization can't honor bounds below its precision at O(1)
/// values, so the bound scales with the dtype (same convention as
/// `tests/api_matrix.rs`).
fn eb_for(dtype: Dtype) -> f64 {
    match dtype {
        Dtype::F32 => 1e-2,
        Dtype::F64 => 1e-4,
    }
}

#[derive(Clone, Default)]
struct SharedCursor(Arc<Mutex<Cursor<Vec<u8>>>>);

impl SharedCursor {
    fn bytes(&self) -> Vec<u8> {
        self.0.lock().unwrap().get_ref().clone()
    }
}

impl Write for SharedCursor {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        self.0.lock().unwrap().write(buf)
    }
    fn flush(&mut self) -> io::Result<()> {
        self.0.lock().unwrap().flush()
    }
}

impl Seek for SharedCursor {
    fn seek(&mut self, pos: SeekFrom) -> io::Result<u64> {
        self.0.lock().unwrap().seek(pos)
    }
}

fn session(dtype: Dtype, codec: Codec) -> Session {
    Session::builder()
        .shape(&SHAPE)
        .dtype(dtype)
        .codec(codec)
        .error_bound(eb_for(dtype))
        .build()
        .unwrap()
}

/// Closely spaced Gray-Scott snapshots (smooth evolution, so delta
/// coding has something to win on), cast to the matrix dtype.
fn snapshots(dtype: Dtype) -> Vec<AnyTensor> {
    GrayScott::snapshots(SHAPE[0], 3, 200, NSTEPS, 2)
        .into_iter()
        .map(|t| AnyTensor::from(t).cast(dtype))
        .collect()
}

#[test]
fn every_step_is_bit_identical_to_standalone_refactoring() {
    let mut delta_ever_won = false;
    for dtype in [Dtype::F32, Dtype::F64] {
        for codec in [Codec::Zlib, Codec::HuffRle] {
            let s = session(dtype, codec);
            let snaps = snapshots(dtype);
            let shared = SharedCursor::default();
            let w = s.stream(shared.clone(), WINDOW).unwrap();
            for snap in &snaps {
                w.push(snap).unwrap();
            }
            let stats = w.finish().unwrap();
            assert_eq!(stats.steps.len(), NSTEPS);
            delta_ever_won |= stats.steps.iter().any(|r| r.encoding == StepEncoding::Delta);
            // closely spaced smooth steps under the default codec must
            // favor deltas overall (mirrors the writer's own unit test)
            if dtype == Dtype::F64 && codec == Codec::Zlib {
                assert!(stats.delta_ratio() < 1.0, "ratio {}", stats.delta_ratio());
            }

            let series = Series::from_bytes(shared.bytes()).unwrap();
            assert_eq!(series.nsteps(), NSTEPS);
            for (t, snap) in snaps.iter().enumerate() {
                let standalone = s.refactor(snap).unwrap();
                for fid in [
                    Fidelity::Classes(1),
                    Fidelity::Classes(2),
                    Fidelity::All,
                    Fidelity::ErrorBound(1e-2),
                ] {
                    let from_stream = series.retrieve_step(t as u64, fid).unwrap();
                    let want = standalone.retrieve(fid).unwrap();
                    assert_eq!(
                        from_stream, want,
                        "{dtype} {codec:?} step {t} at {fid:?} diverged from standalone"
                    );
                }
            }
        }
    }
    assert!(delta_ever_won, "no combination ever chose delta coding");
}

#[test]
fn delta_chains_honor_the_error_bound() {
    for dtype in [Dtype::F32, Dtype::F64] {
        for codec in [Codec::Zlib, Codec::HuffRle] {
            let s = session(dtype, codec);
            let snaps = snapshots(dtype);
            let shared = SharedCursor::default();
            let w = s.stream(shared.clone(), WINDOW).unwrap();
            for snap in &snaps {
                w.push(snap).unwrap();
            }
            w.finish().unwrap();

            let eb = eb_for(dtype);
            let series = Series::from_bytes(shared.bytes()).unwrap();
            for (t, snap) in snaps.iter().enumerate() {
                let info = series.step(t as u64).unwrap();
                let full = series.retrieve_step(t as u64, Fidelity::All).unwrap();
                let err = full.linf_to(snap).unwrap();
                assert!(
                    err <= eb,
                    "{dtype} {codec:?} step {t} ({}) L∞ {err:.3e} exceeds bound {eb:.1e}",
                    if info.delta { "delta" } else { "independent" }
                );
            }
        }
    }
}

#[test]
fn peak_resident_bytes_respect_the_window_bound() {
    let s = session(Dtype::F64, Codec::Zlib);
    let snaps = snapshots(Dtype::F64);
    let step_bytes = snaps[0].nbytes();
    let shared = SharedCursor::default();
    let w = s.stream(shared.clone(), WINDOW).unwrap();
    for snap in &snaps {
        w.push(snap).unwrap();
    }
    let stats = w.finish().unwrap();
    assert_eq!(stats.window, WINDOW);
    // the backpressure contract: at most `window` queued snapshots plus
    // the one the encoder holds, never the whole run
    assert!(
        stats.peak_resident_bytes <= (WINDOW + 1) * step_bytes,
        "peak {} exceeds ({WINDOW} + 1) × {step_bytes}",
        stats.peak_resident_bytes
    );
    assert!(stats.peak_resident_bytes >= step_bytes, "at least one step was resident");
}
