//! Whole-system integration: Gray-Scott data through the coordinator,
//! coefficient classes through the storage mover, progressive fidelity
//! against the visualization metric, and the compression pipeline —
//! the paper's Fig-1 workflow end to end on real simulated data.

use mgr::compress::{Codec, MgardCompressor};
use mgr::coordinator::{Backend, Coordinator, JobMode, JobSpec, ParallelRefactorer};
use mgr::grid::{pad, Hierarchy, Tensor};
use mgr::refactor::{class_norms, recompose_with_classes, select_classes, Refactorer};
use mgr::sim::GrayScott;
use mgr::storage::{place_classes, ParallelFs, ProgressiveReader, ProgressiveWriter, TierSpec};
use mgr::util::stats::{linf, rmse, value_range};
use mgr::vis::iso_surface_area;

fn grayscott_field(n: usize) -> Tensor<f64> {
    let mut sim = GrayScott::new(n, 7);
    sim.step(250);
    sim.v_field()
}

#[test]
fn fig1_workflow_end_to_end() {
    // simulate -> refactor -> container (per-class segments) -> place the
    // REAL entropy-coded byte sizes on tiers -> progressive retrieval ->
    // accuracy vs bytes
    let n = 33;
    let field = grayscott_field(n);
    let h = Hierarchy::uniform(field.shape());
    let eb = 1e-6 * value_range(field.data());
    let mut writer = ProgressiveWriter::<f64>::new(h.clone(), Codec::Zlib);
    let (container, header) = writer.write(&field, eb).unwrap();

    // real compressed segment sizes, not synthetic value counts
    let class_bytes: Vec<u64> = header.segments.iter().map(|s| s.bytes).collect();
    assert!(class_bytes.iter().all(|&b| b > 0));
    assert!(
        class_bytes.iter().sum::<u64>() < field.nbytes() as u64,
        "entropy-coded classes must beat raw bytes on smooth data"
    );
    let tiers = vec![
        TierSpec::burst_buffer(),
        TierSpec::parallel_fs(),
        TierSpec::archive(),
    ];
    let placement = place_classes(&class_bytes, &tiers);
    // coarse classes must land on the fastest tier
    assert_eq!(
        placement.assignment[0],
        mgr::storage::StorageTier::BurstBuffer
    );
    assert!(placement.over_capacity.is_empty());

    // progressive retrieval from the container: more classes -> more
    // bytes, less error
    let mut reader = ProgressiveReader::<f64>::open(&container).unwrap();
    let mut last_err = f64::INFINITY;
    for keep in 1..=h.nclasses() {
        let approx = reader.retrieve(keep).unwrap();
        let err = rmse(approx.data(), field.data());
        assert!(err <= last_err + 1e-12, "keep={keep}");
        last_err = err;
    }
    assert!(last_err <= eb, "full retrieval must satisfy the error bound");

    // the in-memory path must agree with the container path on exact data
    let mut dec = field.clone();
    Refactorer::new(h.clone()).decompose(&mut dec);
    let exact = recompose_with_classes(&dec, &h, h.nclasses());
    assert!(linf(exact.data(), field.data()) < 1e-12);
}

#[test]
fn container_file_roundtrip_with_error_selection() {
    let n = 33;
    let field = grayscott_field(n);
    let h = Hierarchy::uniform(field.shape());
    let range = value_range(field.data());
    let eb = 1e-4 * range;
    let path = std::env::temp_dir().join("mgr_integration_container.mgr");

    let mut writer = ProgressiveWriter::<f64>::new(h.clone(), Codec::HuffRle);
    let header = writer.write_file(&field, eb, &path).unwrap();
    let mut reader = ProgressiveReader::<f64>::open_file(&path).unwrap();
    assert_eq!(reader.nclasses(), h.nclasses());

    // recorded annotations equal measured errors, and --error semantics
    // pick the smallest satisfying prefix
    for (k, seg) in header.segments.iter().enumerate() {
        let approx = reader.retrieve(k + 1).unwrap();
        assert_eq!(seg.linf, linf(approx.data(), field.data()), "class {k}");
    }
    let target = 1e-2 * range;
    let (keep, approx) = reader.retrieve_error(target).unwrap();
    assert!(linf(approx.data(), field.data()) <= target);
    if keep > 1 {
        assert!(
            header.segments[keep - 2].linf > target,
            "a smaller prefix would also have satisfied the target"
        );
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn error_control_selects_enough_classes() {
    let n = 33;
    let field = grayscott_field(n);
    let h = Hierarchy::uniform(field.shape());
    let mut dec = field.clone();
    Refactorer::new(h.clone()).decompose(&mut dec);
    let norms = class_norms(&dec, &h);
    let range = value_range(field.data());
    for rel in [1e-1, 1e-2, 1e-3] {
        let target = rel * range;
        let keep = select_classes(&norms, target);
        let approx = recompose_with_classes(&dec, &h, keep);
        let err = linf(approx.data(), field.data());
        assert!(
            err <= target,
            "rel={rel}: kept {keep} classes, err {err} > {target}"
        );
    }
}

#[test]
fn iso_surface_accuracy_with_few_classes() {
    // §5.1: high iso-surface-area accuracy from a prefix of the classes
    let n = 33;
    let field = grayscott_field(n);
    let h = Hierarchy::uniform(field.shape());
    let mut dec = field.clone();
    Refactorer::new(h.clone()).decompose(&mut dec);

    let iso = 0.25;
    let full_area = iso_surface_area(&field, iso);
    assert!(full_area > 0.0, "iso-surface must exist on this workload");

    let nc = h.nclasses();
    let approx = recompose_with_classes(&dec, &h, nc - 2);
    let area = iso_surface_area(&approx, iso);
    let accuracy = 1.0 - (area - full_area).abs() / full_area;
    assert!(
        accuracy > 0.9,
        "dropping 2 finest classes kept only {:.1}% area accuracy",
        accuracy * 100.0
    );
}

#[test]
fn compression_on_real_simulation_data() {
    let n = 33;
    let field = grayscott_field(n);
    let range = value_range(field.data());
    let eb = 1e-3 * range; // the paper's 1e-3 error bound
    for codec in [Codec::Zlib, Codec::HuffRle] {
        let mut c = MgardCompressor::new(Hierarchy::uniform(field.shape()), codec);
        let blob = c.compress(&field, eb).unwrap();
        let back = c.decompress(&blob).unwrap();
        assert!(linf(back.data(), field.data()) <= eb);
        assert!(
            blob.ratio() > 3.0,
            "{codec:?}: Gray-Scott at 1e-3 should compress >3x, got {:.2}",
            blob.ratio()
        );
    }
}

#[test]
fn padded_non_refactorable_shapes() {
    // a 30^3 field (not 2^k+1) goes through pad -> refactor -> crop
    let mut sim = GrayScott::new(30, 9);
    sim.step(100);
    let field = sim.v_field();
    let padded = pad::pad_to_refactorable(&field);
    assert_eq!(padded.tensor.shape(), &[33, 33, 33]);
    let h = Hierarchy::uniform(padded.tensor.shape());
    let mut t = padded.tensor.clone();
    let mut r = Refactorer::new(h);
    r.decompose(&mut t);
    r.recompose(&mut t);
    let back = pad::crop(&t, &padded.original_shape);
    assert!(linf(back.data(), field.data()) < 1e-10);
}

#[test]
fn coordinator_batch_over_grayscott_snapshots() {
    // several timesteps flow through the worker pool with mixed modes
    let snaps = GrayScott::snapshots(17, 11, 50, 4, 25);
    let jobs: Vec<JobSpec> = snaps
        .into_iter()
        .enumerate()
        .map(|(i, data)| JobSpec {
            name: format!("t{i}"),
            data,
            mode: if i % 2 == 0 {
                JobMode::Serial
            } else {
                JobMode::Cooperative { workers: 2 }
            },
            error_bound: if i == 3 { Some(1e-3) } else { None },
            codec: Codec::Zlib,
        })
        .collect();
    let coord = Coordinator::new(Backend::Native, 3);
    let results = coord.run_batch(jobs);
    assert_eq!(results.len(), 4);
    for r in &results {
        assert!(r.is_ok());
    }
}

#[test]
fn spatiotemporal_vs_spatial_compression_tradeoff() {
    // §4.6 / Fig 15: batching time steps into a 3+1-D hierarchy improves
    // compression over per-step spatial refactoring
    let nt = 5;
    let n = 17;
    let snaps = GrayScott::snapshots(n, 13, 100, nt, 2);
    let mut st_data = Vec::new();
    for s in &snaps {
        st_data.extend_from_slice(s.data());
    }
    let st = Tensor::from_vec(&[nt, n, n, n], st_data);

    let range = value_range(st.data());
    let eb = 1e-3 * range;
    let quant = mgr::compress::QuantMeta::for_bound(eb, 5);

    // spatial-only: decompose each step, quantize, count zlib bytes
    let mut spatial_bytes = 0usize;
    for s in &snaps {
        let mut d = s.clone();
        Refactorer::new(Hierarchy::uniform(s.shape())).decompose(&mut d);
        let q = mgr::compress::quantize(d.data(), &quant).unwrap();
        spatial_bytes += zlib_len(&q);
    }

    // spatiotemporal: one 4-D hierarchy over the batch
    let mut d4 = st.clone();
    Refactorer::spatiotemporal(Hierarchy::uniform(st.shape())).decompose(&mut d4);
    let q4 = mgr::compress::quantize(d4.data(), &quant).unwrap();
    let st_bytes = zlib_len(&q4);

    assert!(
        (st_bytes as f64) < spatial_bytes as f64 * 1.05,
        "spatiotemporal ({st_bytes}) should not exceed spatial ({spatial_bytes})"
    );
}

fn zlib_len(q: &[i64]) -> usize {
    use std::io::Write;
    let raw: Vec<u8> = q.iter().flat_map(|v| v.to_le_bytes()).collect();
    let mut enc = flate2::write::ZlibEncoder::new(Vec::new(), flate2::Compression::default());
    enc.write_all(&raw).unwrap();
    enc.finish().unwrap().len()
}

#[test]
fn parallel_fs_model_consistency() {
    let fs = ParallelFs::alpine();
    // reading a third of the bytes must cut I/O substantially (Fig 18)
    let full = fs.read_time(512, 4e12).unwrap();
    let third = fs.read_time(512, 4e12 / 3.0).unwrap();
    assert!(third < 0.55 * full);
}

#[test]
fn cooperative_refactorer_scales_without_changing_results() {
    let field = grayscott_field(33);
    let h = Hierarchy::uniform(field.shape());
    let mut one = field.clone();
    ParallelRefactorer::new(h.clone(), 1).decompose(&mut one);
    let mut six = field.clone();
    ParallelRefactorer::new(h, 6).decompose(&mut six);
    assert_eq!(one.data(), six.data());
}
