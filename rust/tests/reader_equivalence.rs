//! Reader equivalence suite: the lazy, seekable retrieval path
//! (`storage::reader` + `api::OpenContainer`) must be **bit-identical**
//! to the existing full-buffer path (`storage::container::
//! ProgressiveReader`) for every `Fidelity` variant and both dtypes, and
//! `Retrieved::upgrade` must equal a fresh retrieval while reading only
//! the delta segments. Also holds the acceptance byte-accounting checks
//! (a one-class retrieval touches well under half the container) and the
//! bit-flip regression: validation happens once at open, yet a corrupt
//! segment still fails at its first decode.

use std::io::Cursor;

use mgr::api::{AnyTensor, Codec, Dtype, Fidelity, OpenContainer, Refactored, Session};
use mgr::grid::Tensor;
use mgr::sim::GrayScott;
use mgr::storage::ProgressiveReader;
use mgr::util::stats::value_range;

/// Smooth deterministic field with O(1) values on any shape.
fn field(shape: &[usize], dtype: Dtype) -> AnyTensor {
    let f64_field: AnyTensor = Tensor::<f64>::from_fn(shape, |idx| {
        idx.iter()
            .enumerate()
            .map(|(d, &i)| ((d as f64 + 1.3) * i as f64 * 0.21).sin())
            .product::<f64>()
            + 0.25
    })
    .into();
    f64_field.cast(dtype)
}

/// Serialize a container for the given dtype/codec.
fn container(shape: &[usize], dtype: Dtype, codec: Codec) -> Vec<u8> {
    let eb = match dtype {
        Dtype::F32 => 1e-2,
        Dtype::F64 => 1e-4,
    };
    let session = Session::builder()
        .shape(shape)
        .dtype(dtype)
        .codec(codec)
        .error_bound(eb)
        .build()
        .unwrap();
    let refactored = session.refactor(&field(shape, dtype)).unwrap();
    refactored.as_bytes().to_vec()
}

/// The pre-existing full-buffer retrieval: `ProgressiveReader` parses
/// and buffers every segment payload up front, then decodes a prefix.
fn buffered_retrieve(bytes: &[u8], keep: usize) -> AnyTensor {
    match mgr::storage::container::peek_dtype(bytes).unwrap() {
        4 => {
            let mut r = ProgressiveReader::<f32>::open(bytes).unwrap();
            AnyTensor::F32(r.retrieve(keep).unwrap())
        }
        8 => {
            let mut r = ProgressiveReader::<f64>::open(bytes).unwrap();
            AnyTensor::F64(r.retrieve(keep).unwrap())
        }
        other => panic!("unexpected scalar width {other}"),
    }
}

#[test]
fn lazy_retrieval_bit_identical_to_full_buffer_path() {
    let shape: &[usize] = &[17, 17];
    for dtype in [Dtype::F32, Dtype::F64] {
        for codec in Codec::ALL {
            let label = format!("{dtype} {}", codec.name());
            let bytes = container(shape, dtype, codec);
            let lazy = OpenContainer::open(Cursor::new(bytes.clone())).unwrap();
            let nclasses = lazy.nclasses();
            let header = lazy.header().clone();

            // every Fidelity variant resolves + retrieves identically to
            // the buffered path
            let mut fidelities = vec![Fidelity::All];
            for keep in 1..=nclasses {
                fidelities.push(Fidelity::Classes(keep));
                fidelities.push(Fidelity::ByteBudget(header.prefix_bytes(keep)));
                // resolve rejects a non-positive error target, so only a
                // strictly positive recorded annotation is a valid request
                let recorded = header.segments[keep - 1].linf;
                if recorded > 0.0 {
                    fidelities.push(Fidelity::ErrorBound(recorded));
                }
            }
            for fidelity in fidelities {
                let keep = lazy.resolve(fidelity).unwrap();
                let want = buffered_retrieve(&bytes, keep);
                let got = lazy.retrieve(fidelity).unwrap();
                assert_eq!(got.keep(), keep, "{label} {fidelity:?}");
                assert_eq!(got.tensor(), &want, "{label} {fidelity:?}");
                // the buffered Refactored facade agrees too
                let refactored = Refactored::from_bytes(bytes.clone()).unwrap();
                assert_eq!(refactored.retrieve(fidelity).unwrap(), want, "{label} {fidelity:?}");
            }
        }
    }
}

#[test]
fn upgrade_equals_fresh_retrieval_for_every_step() {
    let shape: &[usize] = &[17, 17];
    for dtype in [Dtype::F32, Dtype::F64] {
        for codec in Codec::ALL {
            let label = format!("{dtype} {}", codec.name());
            let bytes = container(shape, dtype, codec);
            let nclasses = OpenContainer::open(Cursor::new(bytes.clone())).unwrap().nclasses();

            // single-step upgrades: retrieve(k) then upgrade(k+1) equals
            // a fresh retrieve(k+1) from an untouched reader, bitwise
            for keep in 1..nclasses {
                let lazy = OpenContainer::open(Cursor::new(bytes.clone())).unwrap();
                let coarse = lazy.retrieve(Fidelity::Classes(keep)).unwrap();
                let upgraded = coarse.upgrade(Fidelity::Classes(keep + 1)).unwrap();
                assert_eq!(upgraded.keep(), keep + 1, "{label} keep={keep}");
                let fresh = OpenContainer::open(Cursor::new(bytes.clone()))
                    .unwrap()
                    .retrieve(Fidelity::Classes(keep + 1))
                    .unwrap();
                assert_eq!(upgraded.tensor(), fresh.tensor(), "{label} keep={keep}");
            }

            // a chained 1 -> 2 -> ... -> n ladder stays identical to
            // fresh retrievals at every rung
            let lazy = OpenContainer::open(Cursor::new(bytes.clone())).unwrap();
            let mut rung = lazy.retrieve(Fidelity::Classes(1)).unwrap();
            for keep in 2..=nclasses {
                rung = rung.upgrade(Fidelity::Classes(keep)).unwrap();
                assert_eq!(rung.tensor(), &buffered_retrieve(&bytes, keep), "{label} keep={keep}");
            }
        }
    }
}

#[test]
fn prefix_retrieval_reads_less_than_half_and_upgrade_reads_only_delta() {
    // the standard fixture of the container/reader benches: a simulated
    // Gray-Scott field at 33^3
    let mut sim = GrayScott::new(33, 5);
    sim.step(150);
    let raw = sim.v_field();
    let eb = 1e-3 * value_range(raw.data());
    let session = Session::builder()
        .shape(raw.shape())
        .error_bound(eb)
        .build()
        .unwrap();
    let data: AnyTensor = raw.into();
    let bytes = session.refactor(&data).unwrap().as_bytes().to_vec();

    let lazy = OpenContainer::open(Cursor::new(bytes.clone())).unwrap();
    let header = lazy.header().clone();
    let total = lazy.total_bytes();
    assert_eq!(total as usize, bytes.len());
    // the acceptance bound: one class costs under half the container
    let coarse = lazy.retrieve(Fidelity::Classes(1)).unwrap();
    let after_one = lazy.bytes_read();
    assert!(
        after_one * 2 < total,
        "Classes(1) read {after_one} of {total} bytes — not under 50%"
    );
    // every further step reads exactly that segment's recorded bytes
    let mut rung = coarse;
    for keep in 2..=lazy.nclasses() {
        let before = lazy.bytes_read();
        rung = rung.upgrade(Fidelity::Classes(keep)).unwrap();
        let delta = lazy.bytes_read() - before;
        assert_eq!(delta, header.segments[keep - 1].bytes, "keep={keep}");
    }
    // the ladder ends at full fidelity having read the container exactly
    // once
    assert_eq!(rung.keep(), lazy.nclasses());
    assert_eq!(lazy.bytes_read(), total);
    // re-retrieving anything reads nothing new
    lazy.retrieve(Fidelity::All).unwrap();
    assert_eq!(lazy.bytes_read(), total);
}

#[test]
fn bit_flipped_segment_fails_at_first_decode_not_at_open() {
    // zlib segments start with the fixed CMF byte 0x78; flipping it
    // makes the very first decode of that segment fail deterministically
    let bytes = container(&[17, 17], Dtype::F64, Codec::Zlib);
    let (header, header_len) = mgr::storage::ContainerHeader::parse(&bytes).unwrap();
    let nclasses = header.nclasses();

    // flip the first byte of the COARSEST segment: open still succeeds
    // (structural validation only), every retrieval fails at decode
    let mut corrupt = bytes.clone();
    corrupt[header_len] ^= 0xFF;
    let refactored = Refactored::from_bytes(corrupt.clone()).unwrap();
    assert!(refactored.retrieve(Fidelity::Classes(1)).is_err());
    assert!(refactored.retrieve(Fidelity::All).is_err());
    let lazy = OpenContainer::open(Cursor::new(corrupt)).unwrap();
    assert!(lazy.retrieve(Fidelity::Classes(1)).is_err());

    // flip the first byte of the LAST segment: prefixes that never touch
    // it still decode, and the corruption surfaces exactly when the
    // segment is first needed
    let last_offset = header_len as u64 + header.prefix_bytes(nclasses - 1);
    let mut corrupt = bytes.clone();
    corrupt[last_offset as usize] ^= 0xFF;
    let lazy = OpenContainer::open(Cursor::new(corrupt)).unwrap();
    let coarse = lazy.retrieve(Fidelity::Classes(nclasses - 1)).unwrap();
    assert_eq!(coarse.tensor(), &buffered_retrieve(&bytes, nclasses - 1));
    assert!(coarse.upgrade(Fidelity::All).is_err());
}
